//! Shared Optimal-Brain-Surgeon machinery for SparseGPT and GPTQ.
//!
//! Both baselines follow Frantar et al.'s accelerated OBS recipe: work on
//! the *inverse* Hessian `H⁻¹ = (C + λI)⁻¹`, take its upper Cholesky factor
//! `U` (so `H⁻¹ = Uᵀ·U`), then sweep columns left → right. Freezing column
//! `j` to value `q̂` (0 when pruned, a grid point when quantized) incurs
//! error `e = (w_j − q̂)/U[j,j]`, which is optimally redistributed onto the
//! *remaining* columns as `w[j+1:] −= e · U[j, j+1:]`.
//!
//! This is exactly the `O(d_in³)` Hessian-inverse pipeline the paper
//! contrasts AWP's `O(d_out·d_in²)`-per-iteration GEMM against — kept on the
//! same substrates so `benches/compression.rs` measures the real gap.

use crate::linalg;
use crate::tensor::Matrix;

/// Upper Cholesky factor `U` of `(C + λ·mean(diag C)·I)⁻¹` with `H⁻¹=UᵀU`,
/// plus the damping actually used.
pub fn hinv_upper_chol(c: &Matrix, percdamp: f64) -> (Matrix, f64) {
    let hinv = linalg::spd_inverse(c, percdamp.max(1e-8));
    // our cholesky gives lower L with Hinv = L·Lᵀ ⇒ U = Lᵀ
    let (ch, lambda) = linalg::cholesky_damped(&hinv, 0.0);
    (ch.l.transpose(), lambda)
}

/// One row's OBS sweep state: the row is modified in place; `decide` is
/// called once per column with the *current* (error-compensated) value and
/// must return the frozen value for that column.
pub fn sweep_row(row: &mut [f32], u: &Matrix, mut decide: impl FnMut(usize, f32) -> f32) {
    let n = row.len();
    debug_assert_eq!(u.rows, n);
    for j in 0..n {
        let q = row[j];
        let qc = decide(j, q);
        let d = u.at(j, j);
        row[j] = qc;
        if d.abs() < 1e-12 {
            continue;
        }
        let err = (q - qc) / d;
        if err == 0.0 {
            continue;
        }
        let urow = u.row(j);
        for t in j + 1..n {
            row[t] -= err * urow[t];
        }
    }
}

/// Distribute a per-row prune budget over column blocks (SparseGPT's lazy
/// mask selection): returns how many entries to prune in the block ending
/// at `col_end`, given the cumulative target.
pub fn block_prune_budget(total_prune: usize, d_in: usize, col_end: usize,
                          pruned_so_far: usize) -> usize {
    let target_cum =
        ((total_prune as f64) * (col_end as f64) / (d_in as f64)).round() as usize;
    target_cum.saturating_sub(pruned_so_far)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul;

    #[test]
    fn hinv_chol_reconstructs_inverse() {
        let c = Matrix::randn_gram(12, 0);
        let (u, _) = hinv_upper_chol(&c, 0.01);
        // UᵀU ≈ (C + damp)⁻¹ ⇒ (UᵀU)·C ≈ I (up to damping)
        let hinv = matmul(&u.transpose(), &u);
        let prod = matmul(&hinv, &c);
        for i in 0..12 {
            assert!((prod.at(i, i) - 1.0).abs() < 0.1, "diag {}", prod.at(i, i));
        }
    }

    #[test]
    fn upper_triangular() {
        let c = Matrix::randn_gram(8, 1);
        let (u, _) = hinv_upper_chol(&c, 0.01);
        for i in 0..8 {
            for j in 0..i {
                assert_eq!(u.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn sweep_identity_decide_is_noop() {
        let c = Matrix::randn_gram(6, 2);
        let (u, _) = hinv_upper_chol(&c, 0.01);
        let orig = [1.0f32, -2.0, 0.5, 3.0, -0.25, 0.1];
        let mut row = orig;
        sweep_row(&mut row, &u, |_, q| q);
        assert_eq!(row, orig);
    }

    #[test]
    fn sweep_error_compensation_beats_naive_zeroing() {
        // zeroing the first half of a correlated row with OBS compensation
        // must give lower activation loss than plain zeroing.
        let w = Matrix::randn(24, 24, 3);
        let c = Matrix::randn_gram(24, 4);
        let (u, _) = hinv_upper_chol(&c, 0.01);
        let mut wins = 0;
        for i in 0..24 {
            let mut obs_row = w.row(i).to_vec();
            sweep_row(&mut obs_row, &u, |j, q| if j < 12 { 0.0 } else { q });
            let mut naive_row = w.row(i).to_vec();
            for v in naive_row.iter_mut().take(12) {
                *v = 0.0;
            }
            let loss = |row: &[f32]| {
                let th = Matrix::from_vec(1, 24, row.to_vec());
                let wr = Matrix::from_vec(1, 24, w.row(i).to_vec());
                crate::tensor::ops::activation_loss(&wr, &th, &c)
            };
            if loss(&obs_row) < loss(&naive_row) {
                wins += 1;
            }
        }
        assert!(wins >= 20, "OBS only won {wins}/24 rows");
    }

    #[test]
    fn budget_distribution_sums_to_total() {
        let d_in = 100;
        let total = 37;
        let mut pruned = 0;
        for end in [32, 64, 100] {
            pruned += block_prune_budget(total, d_in, end, pruned);
        }
        assert_eq!(pruned, total);
    }
}
