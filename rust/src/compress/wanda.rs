//! Wanda (Sun et al., 2023): prune by `|W[i,j]| · ‖X[j,:]‖₂` — i.e. magnitude
//! scaled by the square root of the Gram diagonal. The paper frames this as
//! approximating `C½` by its diagonal in eq. (3) and uses Wanda's solution
//! as AWP's pruning initialiser, which we do too (`awp::AwpDriver`).

use anyhow::{bail, Result};

use super::traits::{CompressedLayer, CompressionMode, CompressionSpec, LayerCompressor};
use crate::tensor::{ops, topk, Matrix};
use crate::util::Timer;

#[derive(Default)]
pub struct WandaPrune;

/// Wanda keep-mask scores: `|W| * sqrt(diag C)` columnwise.
pub fn wanda_scores(w: &Matrix, c: &Matrix) -> Matrix {
    let scales: Vec<f32> = c.diag().iter().map(|&d| d.max(0.0).sqrt()).collect();
    let mut scores = Matrix::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        let wr = w.row(i);
        let sr = scores.row_mut(i);
        for j in 0..w.cols {
            sr[j] = wr[j].abs() * scales[j];
        }
    }
    scores
}

/// The Wanda solution: W masked to the top-k *scores* per row (weights kept
/// verbatim — Wanda does not update surviving weights).
pub fn wanda_prune(w: &Matrix, c: &Matrix, k: usize) -> Matrix {
    let scores = wanda_scores(w, c);
    let mask = topk::row_topk_mask(&scores, k);
    let mut theta = w.clone();
    topk::apply_mask(&mut theta, &mask);
    theta
}

/// Wanda with an N:M pattern (paper §5 / Wanda's own semi-structured
/// variant, generalised): per aligned group of `m`, keep the `n` entries
/// with the largest activation-scaled scores. The AWP driver uses this as
/// the initialiser for N:M-constrained PGD.
pub fn wanda_prune_nm(w: &Matrix, c: &Matrix, n: usize, m: usize) -> Matrix {
    assert!(n >= 1 && m >= 2 && n <= m, "N:M needs 1 <= N <= M, got {n}:{m}");
    let scores = wanda_scores(w, c);
    let mut theta = w.clone();
    for i in 0..w.rows {
        let srow = scores.row(i);
        let trow = theta.row_mut(i);
        for g in (0..srow.len()).step_by(m) {
            let end = (g + m).min(srow.len());
            let mut idx: Vec<usize> = (g..end).collect();
            idx.sort_by(|&a, &b| srow[b].partial_cmp(&srow[a]).unwrap());
            for &j in idx.iter().skip(n) {
                trow[j] = 0.0;
            }
        }
    }
    theta
}

/// [`wanda_prune_nm`] at the NVIDIA 2:4 pattern.
pub fn wanda_prune_2_4(w: &Matrix, c: &Matrix) -> Matrix {
    wanda_prune_nm(w, c, 2, 4)
}

impl LayerCompressor for WandaPrune {
    fn name(&self) -> &'static str {
        "wanda"
    }

    fn compress(&self, w: &Matrix, c: &Matrix, spec: &CompressionSpec)
        -> Result<CompressedLayer> {
        let t = Timer::start("wanda");
        let theta = match spec.mode {
            CompressionMode::Prune { .. } => {
                wanda_prune(w, c, spec.keep_k(w.cols).unwrap())
            }
            CompressionMode::StructuredNm { n, m } => wanda_prune_nm(w, c, n, m),
            _ => bail!("wanda supports Prune/StructuredNm (use sequential for combos)"),
        };
        Ok(CompressedLayer::from_theta(w, c, theta, 0, t.elapsed_s()))
    }
}

/// Convenience used in several tests/benches: activation loss of the Wanda
/// solution at ratio `p`.
pub fn wanda_loss(w: &Matrix, c: &Matrix, ratio: f64) -> f64 {
    let k = (((1.0 - ratio) * w.cols as f64).round() as usize).clamp(1, w.cols);
    ops::activation_loss(w, &wanda_prune(w, c, k), c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_sparsity_exact() {
        let w = Matrix::randn(8, 32, 0);
        let c = Matrix::randn_gram(32, 1);
        let out = WandaPrune.compress(&w, &c, &CompressionSpec::prune(0.5)).unwrap();
        for i in 0..8 {
            assert_eq!(out.theta.row(i).iter().filter(|&&v| v != 0.0).count(), 16);
        }
    }

    #[test]
    fn equals_magnitude_when_c_isotropic() {
        let w = Matrix::randn(6, 16, 2);
        let c = Matrix::eye(16);
        let wd = wanda_prune(&w, &c, 8);
        let mag = topk::hard_threshold_rows(&w, 8);
        assert_eq!(wd, mag);
    }

    #[test]
    fn beats_magnitude_on_anisotropic_gram() {
        // the core activation-aware effect (Tables 1–2, 50% row):
        // averaged over seeds, scaling by sqrt(diag C) must reduce the
        // activation-aware loss vs plain magnitude.
        let mut wins = 0;
        for seed in 0..10 {
            let w = Matrix::randn(32, 64, seed);
            let c = Matrix::randn_gram(64, 100 + seed);
            let wd = ops::activation_loss(&w, &wanda_prune(&w, &c, 32), &c);
            let mag = ops::activation_loss(
                &w,
                &topk::hard_threshold_rows(&w, 32),
                &c,
            );
            if wd < mag {
                wins += 1;
            }
        }
        assert!(wins >= 8, "wanda won only {wins}/10");
    }

    #[test]
    fn survivors_unchanged() {
        let w = Matrix::randn(4, 16, 3);
        let c = Matrix::randn_gram(16, 4);
        let theta = wanda_prune(&w, &c, 4);
        for (a, b) in w.data.iter().zip(&theta.data) {
            assert!(*b == 0.0 || a == b);
        }
    }
}
