//! Sequential pruning/quantization combos — the §4.3 baselines.
//!
//! * **AWQ+Wanda** (quantize first): AWQ-quantize `W`, then Wanda-prune the
//!   quantized weights. The paper finds this consistently *worse*.
//! * **Wanda+AWQ** (prune first): Wanda-prune `W`, then AWQ-quantize the
//!   survivors and re-apply the mask. Consistently better — which our
//!   Table-4/5 regenerations must reproduce.

use anyhow::{bail, Result};

use super::awq::AwqQuant;
use super::traits::{CompressedLayer, CompressionMode, CompressionSpec, LayerCompressor};
use super::wanda;
use crate::tensor::Matrix;
use crate::util::Timer;

/// Which order to apply the two stages in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Order {
    /// AWQ then Wanda (quantize → prune)
    QuantThenPrune,
    /// Wanda then AWQ (prune → quantize, mask re-applied)
    PruneThenQuant,
}

pub struct SequentialCombo {
    pub order: Order,
    pub awq: AwqQuant,
}

impl SequentialCombo {
    pub fn awq_then_wanda() -> Self {
        SequentialCombo { order: Order::QuantThenPrune, awq: AwqQuant::default() }
    }

    pub fn wanda_then_awq() -> Self {
        SequentialCombo { order: Order::PruneThenQuant, awq: AwqQuant::default() }
    }
}

impl LayerCompressor for SequentialCombo {
    fn name(&self) -> &'static str {
        match self.order {
            Order::QuantThenPrune => "awq+wanda",
            Order::PruneThenQuant => "wanda+awq",
        }
    }

    fn grid_refit_checkable(&self) -> bool {
        false
    }

    fn compress(&self, w: &Matrix, c: &Matrix, spec: &CompressionSpec)
        -> Result<CompressedLayer> {
        let t = Timer::start("sequential");
        let CompressionMode::Joint { spec: qs, .. } = spec.mode else {
            bail!("sequential combos require Joint mode");
        };
        let k = spec.keep_k(w.cols).unwrap();
        let qspec = CompressionSpec::quant(qs.bits, qs.group);
        let theta = match self.order {
            Order::QuantThenPrune => {
                let q = self.awq.compress(w, c, &qspec)?.theta;
                // Wanda mask computed on the quantized weights
                wanda::wanda_prune(&q, c, k)
            }
            Order::PruneThenQuant => {
                let pruned = wanda::wanda_prune(w, c, k);
                let mut q = self.awq.compress(&pruned, c, &qspec)?.theta;
                for (qq, p) in q.data.iter_mut().zip(&pruned.data) {
                    if *p == 0.0 {
                        *qq = 0.0;
                    }
                }
                q
            }
        };
        Ok(CompressedLayer::from_theta(w, c, theta, 0, t.elapsed_s()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SparsityStats;

    #[test]
    fn both_orders_satisfy_sparsity() {
        let w = Matrix::randn(16, 64, 0);
        let c = Matrix::randn_gram(64, 1);
        let spec = CompressionSpec::joint(0.5, 4, 32);
        for combo in [SequentialCombo::awq_then_wanda(),
                      SequentialCombo::wanda_then_awq()] {
            let out = combo.compress(&w, &c, &spec).unwrap();
            let s = SparsityStats::of(&out.theta);
            assert!(s.ratio() >= 0.49, "{}: {}", combo.name(), s.ratio());
            assert!(s.is_row_uniform());
        }
    }

    #[test]
    fn prune_first_usually_wins() {
        // Table 4/5 ordering: Wanda+AWQ <= AWQ+Wanda in activation loss
        // on most layers.
        let mut wins = 0;
        for seed in 0..8 {
            let w = Matrix::randn(24, 64, seed);
            let c = Matrix::randn_gram(64, 40 + seed);
            let spec = CompressionSpec::joint(0.5, 4, 32);
            let a = SequentialCombo::wanda_then_awq().compress(&w, &c, &spec).unwrap();
            let b = SequentialCombo::awq_then_wanda().compress(&w, &c, &spec).unwrap();
            if a.stats.final_loss <= b.stats.final_loss {
                wins += 1;
            }
        }
        assert!(wins >= 5, "prune-first won only {wins}/8");
    }

    #[test]
    fn rejects_non_joint() {
        let w = Matrix::randn(4, 32, 3);
        let c = Matrix::randn_gram(32, 4);
        assert!(SequentialCombo::wanda_then_awq()
            .compress(&w, &c, &CompressionSpec::prune(0.5))
            .is_err());
    }
}
