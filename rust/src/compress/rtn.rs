//! Round-To-Nearest quantization — the straightforward non-activation-aware
//! baseline the paper uses as AWP's quantization initialiser (§4.2).

use anyhow::{bail, Result};

use super::traits::{CompressedLayer, CompressionMode, CompressionSpec, LayerCompressor};
use crate::quant;
use crate::tensor::Matrix;
use crate::util::Timer;

#[derive(Default)]
pub struct RtnQuant;

impl LayerCompressor for RtnQuant {
    fn name(&self) -> &'static str {
        "rtn"
    }

    fn compress(&self, w: &Matrix, c: &Matrix, spec: &CompressionSpec)
        -> Result<CompressedLayer> {
        let t = Timer::start("rtn");
        let CompressionMode::Quant { spec: qs } = spec.mode else {
            bail!("rtn only supports Quant mode");
        };
        let theta = quant::quantize_dequantize(w, qs);
        Ok(CompressedLayer::from_theta(w, c, theta, 0, t.elapsed_s()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_decreases_with_bits() {
        let w = Matrix::randn(16, 64, 0);
        let c = Matrix::randn_gram(64, 1);
        let mut prev = f64::MAX;
        for bits in [2u8, 3, 4, 8] {
            let out = RtnQuant
                .compress(&w, &c, &CompressionSpec::quant(bits, 32))
                .unwrap();
            assert!(out.stats.final_loss < prev, "bits={bits}");
            prev = out.stats.final_loss;
        }
    }

    #[test]
    fn satisfies_constraints() {
        let w = Matrix::randn(8, 32, 2);
        let c = Matrix::randn_gram(32, 3);
        let spec = CompressionSpec::quant(4, 32);
        let out = RtnQuant.compress(&w, &c, &spec).unwrap();
        super::super::traits::check_constraints(&out.theta, &spec).unwrap();
    }
}
