//! The AWP driver — Algorithm 1 of the paper, with the experiment section's
//! hyper-parameters and schedules, generic over the compute backend.
//!
//! Two backends implement [`AwpBackend`]:
//!
//! * [`super::awp_cpu::CpuBackend`] — pure-Rust mirror (reference and
//!   fallback; also what the property tests sweep);
//! * `runtime::HloBackend` — the production path: the chunked PGD programs
//!   AOT-compiled from the L2/L1 JAX+Pallas stack, executed via PJRT.
//!
//! Both expose *chunked* iteration (n PGD steps per call returning the
//! iterate plus `‖(W−Θ)C‖_F/‖W‖_F` and the Figure-1 rel-loss), so the
//! driver logic — init, step size, stopping rule, §4.3 ramp schedule, best-
//! iterate tracking — is written once and tested once.

use anyhow::Result;

use super::schedule::{JointPhase, JointSchedule};
use super::traits::{
    CompressStats, CompressedLayer, CompressionMode, CompressionSpec, LayerCompressor,
};
use super::wanda;
use crate::quant;
use crate::tensor::{ops, Matrix};
use crate::util::Timer;

/// Chunked-PGD compute backend (CPU mirror or AOT/PJRT).
pub trait AwpBackend: Send + Sync {
    /// `iters` iterations of `Θ ← H_k(Θ + η(W−Θ)C)`.
    /// Returns `(Θ', rel_grad, rel_loss)`.
    fn prune_chunk(&self, w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32,
                   k: usize, iters: usize) -> Result<(Matrix, f64, f64)>;

    /// `iters` iterations of `Θ ← Proj_INT(Θ + η(W−Θ)C)`.
    fn quant_chunk(&self, w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32,
                   qmax: f32, group: usize, iters: usize)
        -> Result<(Matrix, f64, f64)>;

    /// `iters` iterations of `Θ ← Proj_INT(Proj_row(Θ + η(W−Θ)C))` with the
    /// pruning mask re-applied after quantization. `qmax <= 0` disables the
    /// quantization projection (pure pruning — used by the ramp phase).
    fn joint_chunk(&self, w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32,
                   k: usize, qmax: f32, group: usize, iters: usize)
        -> Result<(Matrix, f64, f64)>;

    /// `iters` iterations with the 2:4 structured projection (paper §5
    /// future work). Optional: only the CPU backend implements it (the AOT
    /// artifact set covers the paper's evaluated constraint sets).
    fn prune24_chunk(&self, _w: &Matrix, _theta: &Matrix, _c: &Matrix,
                     _eta: f32, _iters: usize) -> Result<(Matrix, f64, f64)> {
        anyhow::bail!("2:4 structured pruning is not supported by this backend \
                       (use awp-cpu)")
    }

    fn backend_name(&self) -> &'static str;
}

/// Hyper-parameters, defaults straight from the paper's §4.
#[derive(Clone, Copy, Debug)]
pub struct AwpHyper {
    /// pruning step size = `prune_eta_scale / ‖C‖_F` (paper: 2.0)
    pub prune_eta_scale: f64,
    /// quant/joint step size = `quant_eta_scale / ‖C‖_F` (paper: 1.5)
    pub quant_eta_scale: f64,
    /// pruning stop: `‖(W−Θ)C‖_F/‖W‖_F < prune_tol` (paper: 1e-4)
    pub prune_tol: f64,
    /// pruning iteration cap (paper: 200)
    pub prune_max_iters: usize,
    /// quantization iteration budget (paper: 10)
    pub quant_iters: usize,
    /// §4.3 joint schedule
    pub joint: JointSchedule,
    /// PGD iterations folded per backend call (matches the AOT chunk)
    pub chunk: usize,
    /// quantization group size (paper: 128 at Llama scale; 32 here)
    pub group: usize,
    /// record the per-iteration rel-loss series (Figure 1; forces chunk=1)
    pub track_series: bool,
}

impl Default for AwpHyper {
    fn default() -> Self {
        AwpHyper {
            prune_eta_scale: 2.0,
            quant_eta_scale: 1.5,
            prune_tol: 1e-4,
            prune_max_iters: 200,
            quant_iters: 10,
            joint: JointSchedule::default(),
            chunk: 8,
            group: 32,
            track_series: false,
        }
    }
}

/// The AWP compressor: driver + backend.
pub struct AwpDriver<B: AwpBackend> {
    pub backend: B,
    pub hyper: AwpHyper,
}

impl<B: AwpBackend> AwpDriver<B> {
    pub fn new(backend: B) -> Self {
        AwpDriver { backend, hyper: AwpHyper::default() }
    }

    pub fn with_hyper(backend: B, hyper: AwpHyper) -> Self {
        AwpDriver { backend, hyper }
    }

    fn rel_loss(w: &Matrix, theta: &Matrix, c: &Matrix) -> f64 {
        ops::activation_loss(w, theta, c).sqrt() / w.frob_norm().max(1e-30)
    }

    /// The shared §4.1 IHT driver loop: chunked backend steps from `init`
    /// with the paper's step size and stopping rule (rel-grad < tol or 200
    /// iters), optional per-iteration series tracking. `step(θ, iters)`
    /// performs `iters` backend iterations and returns
    /// `(Θ', rel_grad, rel_loss)` — the only thing that differs between
    /// the row-k and 2:4 constraint sets.
    fn run_iht<S>(&self, w: &Matrix, c: &Matrix, init: Matrix, step: S)
        -> Result<(Matrix, CompressStats)>
    where
        S: Fn(&Matrix, usize) -> Result<(Matrix, f64, f64)>,
    {
        let h = &self.hyper;
        let mut theta = init;
        let mut series = Vec::new();
        if h.track_series {
            series.push(Self::rel_loss(w, &theta, c));
        }
        let chunk = if h.track_series { 1 } else { h.chunk.max(1) };
        let mut iters = 0usize;
        let mut rel = f64::MAX;
        while iters < h.prune_max_iters {
            let n = chunk.min(h.prune_max_iters - iters);
            let (t2, rel_grad, rel_loss) = step(&theta, n)?;
            theta = t2;
            iters += n;
            rel = rel_grad;
            if h.track_series {
                series.push(rel_loss);
            }
            if rel_grad < h.prune_tol {
                break;
            }
        }
        Ok((theta, CompressStats { iterations: iters, loss_series: series,
                                   rel_loss: rel, ..Default::default() }))
    }

    /// §4.1 pruning: Wanda init, η = 2/‖C‖_F, stop at tol or 200 iters.
    fn run_prune(&self, w: &Matrix, c: &Matrix, k: usize)
        -> Result<(Matrix, CompressStats)> {
        let eta = (self.hyper.prune_eta_scale / c.frob_norm().max(1e-30)) as f32;
        self.run_iht(w, c, wanda::wanda_prune(w, c, k), |theta, iters| {
            self.backend.prune_chunk(w, theta, c, eta, k, iters)
        })
    }

    /// §5 future-work extension: IHT with the 2:4 structured projection,
    /// initialised from the Wanda-2:4 mask; same step size / stopping rule
    /// as §4.1 pruning.
    fn run_prune24(&self, w: &Matrix, c: &Matrix) -> Result<(Matrix, CompressStats)> {
        let eta = (self.hyper.prune_eta_scale / c.frob_norm().max(1e-30)) as f32;
        self.run_iht(w, c, wanda::wanda_prune_2_4(w, c), |theta, iters| {
            self.backend.prune24_chunk(w, theta, c, eta, iters)
        })
    }

    /// §4.2 quantization: RTN init, η = 1.5/‖C‖_F, 10 iterations, keeping
    /// the best iterate by rel-loss (the raw sequence can drift once the
    /// re-fitted grid stops improving; see python/tests/test_awp.py).
    fn run_quant(&self, w: &Matrix, c: &Matrix, qmax: f32)
        -> Result<(Matrix, CompressStats)> {
        let h = &self.hyper;
        let eta = (h.quant_eta_scale / c.frob_norm().max(1e-30)) as f32;
        let spec = quant::QuantSpec::new(qmax_bits(qmax), h.group);
        let mut theta = quant::quantize_dequantize(w, spec);
        let mut best = theta.clone();
        let mut best_loss = Self::rel_loss(w, &theta, c);
        let mut series = vec![best_loss];
        for _ in 0..h.quant_iters {
            let (t2, _g, rel_loss) =
                self.backend.quant_chunk(w, &theta, c, eta, qmax, h.group, 1)?;
            theta = t2;
            series.push(rel_loss);
            if rel_loss < best_loss {
                best_loss = rel_loss;
                best = theta.clone();
            }
        }
        Ok((best, CompressStats {
            iterations: h.quant_iters,
            loss_series: if h.track_series { series } else { Vec::new() },
            ..Default::default()
        }))
    }

    /// §4.3 joint: ramp pruning 0→target over 25 iters, prune-only to 50,
    /// then joint prune+quant to 100; best constraint-satisfying iterate.
    ///
    /// Deviation (documented in DESIGN.md §Deviations): the paper leaves the
    /// joint initialisation unspecified. Ramping plain IHT from `Θ=W` makes
    /// the magnitude threshold lock in a *non*-activation-aware mask (the
    /// gradient vanishes at W), which collapses to magnitude-pruning quality.
    /// Consistent with the paper's own §4.1 convention ("initialize Θ(0) as
    /// the solution of Wanda"), the ramp anneals through Wanda solutions at
    /// the scheduled ratio; PGD takes over from iteration 25 exactly as
    /// written.
    fn run_joint(&self, w: &Matrix, c: &Matrix, k: usize, qmax: f32)
        -> Result<(Matrix, CompressStats)> {
        let h = &self.hyper;
        let eta = (h.quant_eta_scale / c.frob_norm().max(1e-30)) as f32;
        let mut theta = w.clone();
        let mut best: Option<(f64, Matrix)> = None;
        let mut series = Vec::new();
        let mut it = 0usize;
        while it < h.joint.total_iters {
            let phase = h.joint.phase(it);
            let k_now = h.joint.k_at(it, w.cols, k);
            if phase == JointPhase::Ramp {
                // annealed Wanda schedule (activation-aware mask at k_now)
                theta = wanda::wanda_prune(w, c, k_now);
                if h.track_series {
                    series.push(Self::rel_loss(w, &theta, c));
                }
                it += 1;
                continue;
            }
            // chunk must not straddle a phase change
            let mut step = match phase {
                JointPhase::Ramp => unreachable!(),
                JointPhase::PruneHold => {
                    h.chunk.min(h.joint.prune_only_iters - it)
                }
                JointPhase::Joint => h.chunk.min(h.joint.total_iters - it),
            };
            if h.track_series {
                step = 1;
            }
            let q_now = if phase == JointPhase::Joint { qmax } else { 0.0 };
            let (t2, _g, rel_loss) =
                self.backend.joint_chunk(w, &theta, c, eta, k_now, q_now, h.group, step)?;
            theta = t2;
            it += step;
            if h.track_series {
                series.push(rel_loss);
            }
            if phase == JointPhase::Joint
                && best.as_ref().map_or(true, |(b, _)| rel_loss < *b)
            {
                best = Some((rel_loss, theta.clone()));
            }
        }
        let theta = best.map(|(_, t)| t).unwrap_or(theta);
        Ok((theta, CompressStats {
            iterations: h.joint.total_iters,
            loss_series: series,
            ..Default::default()
        }))
    }
}

/// bits for a `2^b − 1` qmax (inverse of `QuantSpec::qmax`)
pub fn qmax_bits(qmax: f32) -> u8 {
    let b = ((qmax + 1.0).log2()).round() as i32;
    b.clamp(1, 8) as u8
}

impl<B: AwpBackend> LayerCompressor for AwpDriver<B> {
    fn name(&self) -> &'static str {
        "awp"
    }

    fn compress(&self, w: &Matrix, c: &Matrix, spec: &CompressionSpec)
        -> Result<CompressedLayer> {
        let t = Timer::start("awp");
        let (theta, partial) = match spec.mode {
            CompressionMode::Prune { .. } => {
                self.run_prune(w, c, spec.keep_k(w.cols).unwrap())?
            }
            CompressionMode::Quant { spec: qs } => {
                assert_eq!(qs.group, self.hyper.group,
                           "quant group must match AOT artifacts");
                self.run_quant(w, c, qs.qmax())?
            }
            CompressionMode::Joint { spec: qs, .. } => {
                assert_eq!(qs.group, self.hyper.group);
                self.run_joint(w, c, spec.keep_k(w.cols).unwrap(), qs.qmax())?
            }
            CompressionMode::Structured24 => self.run_prune24(w, c)?,
        };
        let mut out = CompressedLayer::from_theta(w, c, theta, partial.iterations,
                                                  t.elapsed_s());
        out.stats.loss_series = partial.loss_series;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_bits_roundtrip() {
        for bits in 1..=8u8 {
            let qmax = ((1u32 << bits) - 1) as f32;
            assert_eq!(qmax_bits(qmax), bits);
        }
    }
}
