//! The AWP driver — Algorithm 1 of the paper, with the experiment section's
//! hyper-parameters and schedules, generic over the compute backend.
//!
//! Two backends implement [`AwpBackend`]:
//!
//! * [`super::awp_cpu::CpuBackend`] — pure-Rust mirror (reference and
//!   fallback; also what the property tests sweep);
//! * `runtime::HloBackend` — the production path: the chunked PGD programs
//!   AOT-compiled from the L2/L1 JAX+Pallas stack, executed via PJRT.
//!
//! Both expose one *chunked* primitive, [`AwpBackend::step_chunk`]: `iters`
//! iterations of `Θ ← Proj(Θ + η(W−Θ)C)` for an arbitrary
//! [`Projection`], operating on a [`PgdWorkspace`] (two preallocated
//! ping-pong buffers — the inner loop allocates nothing after warm-up) and
//! returning `‖(W−Θ)C‖_F/‖W‖_F` plus the Figure-1 rel-loss. The driver
//! logic — init, step size, stopping rule, §4.3 ramp schedule, best-
//! iterate tracking — is written once and parameterised by the projection,
//! so pruning (row-k or N:M), quantization and every intersection share
//! one code path.

use anyhow::Result;

use super::schedule::JointSchedule;
use super::traits::{
    CompressStats, CompressedLayer, CompressionMode, CompressionSpec, LayerCompressor,
};
use super::wanda;
use crate::proj::{GroupedIntGrid, Intersect, NmStructured, PgdWorkspace, Projection, RowTopK};
use crate::quant::{self, QuantSpec};
use crate::tensor::{ops, Matrix};
use crate::util::Timer;

/// Chunked-PGD compute backend (CPU mirror or AOT/PJRT).
pub trait AwpBackend: Send + Sync {
    /// `iters` iterations of `Θ ← Proj(Θ + η(W−Θ)C)` on the workspace's
    /// current iterate, in place. Returns `(rel_grad, rel_loss)` =
    /// `(‖(W−Θ)C‖_F/‖W‖_F, ‖(W−Θ)C½‖_F/‖W‖_F)` at the final iterate.
    ///
    /// Backends without a lowering for `proj` (see [`Projection::kind`])
    /// fail with a clear error pointing at the CPU backend.
    fn step_chunk(&self, w: &Matrix, c: &Matrix, eta: f32, proj: &dyn Projection,
                  iters: usize, ws: &mut PgdWorkspace) -> Result<(f64, f64)>;

    fn backend_name(&self) -> &'static str;

    /// Convenience for tests and one-off callers: one chunk from an
    /// explicit iterate, allocating a fresh workspace.
    fn step_chunk_from(&self, w: &Matrix, theta: &Matrix, c: &Matrix, eta: f32,
                       proj: &dyn Projection, iters: usize)
        -> Result<(Matrix, f64, f64)> {
        let mut ws = PgdWorkspace::new(theta.clone());
        let (g, l) = self.step_chunk(w, c, eta, proj, iters, &mut ws)?;
        Ok((ws.into_theta(), g, l))
    }
}

/// Hyper-parameters, defaults straight from the paper's §4.
#[derive(Clone, Copy, Debug)]
pub struct AwpHyper {
    /// pruning step size = `prune_eta_scale / ‖C‖_F` (paper: 2.0)
    pub prune_eta_scale: f64,
    /// quant/joint step size = `quant_eta_scale / ‖C‖_F` (paper: 1.5)
    pub quant_eta_scale: f64,
    /// pruning stop: `‖(W−Θ)C‖_F/‖W‖_F < prune_tol` (paper: 1e-4)
    pub prune_tol: f64,
    /// pruning iteration cap (paper: 200)
    pub prune_max_iters: usize,
    /// quantization iteration budget (paper: 10)
    pub quant_iters: usize,
    /// §4.3 joint schedule
    pub joint: JointSchedule,
    /// PGD iterations folded per backend call (matches the AOT chunk)
    pub chunk: usize,
    /// quantization group size (paper: 128 at Llama scale; 32 here)
    pub group: usize,
    /// record the per-iteration rel-loss series (Figure 1; forces chunk=1)
    pub track_series: bool,
}

impl Default for AwpHyper {
    fn default() -> Self {
        AwpHyper {
            prune_eta_scale: 2.0,
            quant_eta_scale: 1.5,
            prune_tol: 1e-4,
            prune_max_iters: 200,
            quant_iters: 10,
            joint: JointSchedule::default(),
            chunk: 8,
            group: 32,
            track_series: false,
        }
    }
}

impl AwpHyper {
    /// Content fingerprint over every Θ-affecting knob — the method-
    /// parameter component of a compressed-artifact key
    /// (`crate::artifact::ArtifactKey::params`). Step sizes, iteration
    /// budgets, the joint schedule and the AOT chunk/group all change the
    /// produced weights, so artifacts computed under different
    /// hyperparameters must never collide. (`track_series` is excluded:
    /// it only adds bookkeeping, not a different Θ.)
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::Fnv64::new();
        h.write_f64(self.prune_eta_scale);
        h.write_f64(self.quant_eta_scale);
        h.write_f64(self.prune_tol);
        h.write_usize(self.prune_max_iters);
        h.write_usize(self.quant_iters);
        h.write_usize(self.joint.total_iters);
        h.write_usize(self.joint.ramp_iters);
        h.write_usize(self.joint.prune_only_iters);
        h.write_usize(self.chunk);
        h.write_usize(self.group);
        h.finish()
    }
}

/// The AWP compressor: driver + backend.
pub struct AwpDriver<B: AwpBackend> {
    pub backend: B,
    pub hyper: AwpHyper,
}

impl<B: AwpBackend> AwpDriver<B> {
    pub fn new(backend: B) -> Self {
        AwpDriver { backend, hyper: AwpHyper::default() }
    }

    pub fn with_hyper(backend: B, hyper: AwpHyper) -> Self {
        AwpDriver { backend, hyper }
    }

    fn rel_loss(w: &Matrix, theta: &Matrix, c: &Matrix) -> f64 {
        ops::rel_activation_loss(w, theta, c)
    }

    /// Best-iterate tracking shared by the joint drivers: keep the lowest
    /// rel-loss iterate seen, reusing the kept buffer's allocation on
    /// updates (`clone_from`).
    fn track_best(best: &mut Option<(f64, Matrix)>, rel_loss: f64, theta: &Matrix) {
        if best.as_ref().map_or(true, |(b, _)| rel_loss < *b) {
            match best {
                Some((bl, bm)) => {
                    *bl = rel_loss;
                    bm.clone_from(theta);
                }
                None => *best = Some((rel_loss, theta.clone())),
            }
        }
    }

    /// The shared §4.1 IHT driver loop: chunked backend steps from `init`
    /// under `proj`, with the paper's stopping rule (rel-grad < tol or 200
    /// iters) and optional per-iteration series tracking. The constraint
    /// set (row-k vs N:M) is entirely the projection's business.
    fn run_iht(&self, w: &Matrix, c: &Matrix, init: Matrix, eta: f32,
               proj: &dyn Projection) -> Result<(Matrix, CompressStats)> {
        let h = &self.hyper;
        let mut ws = PgdWorkspace::new(init);
        let mut series = Vec::new();
        if h.track_series {
            series.push(Self::rel_loss(w, ws.theta(), c));
        }
        let chunk = if h.track_series { 1 } else { h.chunk.max(1) };
        let mut iters = 0usize;
        let mut rel = f64::MAX;
        while iters < h.prune_max_iters {
            let n = chunk.min(h.prune_max_iters - iters);
            let (rel_grad, rel_loss) =
                self.backend.step_chunk(w, c, eta, proj, n, &mut ws)?;
            iters += n;
            rel = rel_grad;
            if h.track_series {
                series.push(rel_loss);
            }
            if rel_grad < h.prune_tol {
                break;
            }
        }
        Ok((ws.into_theta(),
            CompressStats { iterations: iters, loss_series: series,
                            rel_loss: rel, ..Default::default() }))
    }

    /// §4.1 pruning: Wanda init, η = 2/‖C‖_F, stop at tol or 200 iters.
    fn run_prune(&self, w: &Matrix, c: &Matrix, k: usize)
        -> Result<(Matrix, CompressStats)> {
        let eta = (self.hyper.prune_eta_scale / c.frob_norm().max(1e-30)) as f32;
        self.run_iht(w, c, wanda::wanda_prune(w, c, k), eta, &RowTopK::new(k))
    }

    /// §5 future-work extension generalised: IHT with an N:M structured
    /// projection, initialised from the Wanda-N:M mask; same step size /
    /// stopping rule as §4.1 pruning. `(2, 4)` is the NVIDIA pattern.
    fn run_prune_nm(&self, w: &Matrix, c: &Matrix, n: usize, m: usize)
        -> Result<(Matrix, CompressStats)> {
        let eta = (self.hyper.prune_eta_scale / c.frob_norm().max(1e-30)) as f32;
        self.run_iht(w, c, wanda::wanda_prune_nm(w, c, n, m), eta,
                     &NmStructured::new(n, m))
    }

    /// §4.2 quantization: RTN init, η = 1.5/‖C‖_F, 10 iterations, keeping
    /// the best iterate by rel-loss (the raw sequence can drift once the
    /// re-fitted grid stops improving; see python/tests/test_awp.py). The
    /// series is collected only under `track_series`, and the best iterate
    /// is kept via `clone_from` into one reused buffer — the loop performs
    /// no per-iteration allocations beyond that buffer's warm-up.
    fn run_quant(&self, w: &Matrix, c: &Matrix, qs: QuantSpec)
        -> Result<(Matrix, CompressStats)> {
        let h = &self.hyper;
        let eta = (h.quant_eta_scale / c.frob_norm().max(1e-30)) as f32;
        let proj = GroupedIntGrid::new(qs.qmax(), h.group);
        let init = quant::quantize_dequantize(w, QuantSpec::new(qs.bits, h.group));
        let mut ws = PgdWorkspace::new(init);
        let mut best_loss = Self::rel_loss(w, ws.theta(), c);
        let mut best = ws.theta().clone();
        let mut series = if h.track_series { vec![best_loss] } else { Vec::new() };
        for _ in 0..h.quant_iters {
            let (_g, rel_loss) = self.backend.step_chunk(w, c, eta, &proj, 1, &mut ws)?;
            if h.track_series {
                series.push(rel_loss);
            }
            if rel_loss < best_loss {
                best_loss = rel_loss;
                best.clone_from(ws.theta());
            }
        }
        Ok((best, CompressStats {
            iterations: h.quant_iters,
            loss_series: series,
            ..Default::default()
        }))
    }

    /// The §4.3 hold → joint tail shared by both joint drivers: sparse-only
    /// PGD from iteration `start` up to `prune_only_iters`, then
    /// sparse ∩ grid to `total_iters`, tracking the best joint-phase
    /// iterate. `sparse` is the constraint's sparsity half (row-top-k at
    /// the target ratio, or N:M); chunks never straddle the phase change.
    fn run_joint_phases<S: Projection + Copy>(
        &self, w: &Matrix, c: &Matrix, eta: f32, qmax: f32, sparse: S,
        mut ws: PgdWorkspace, start: usize, mut series: Vec<f64>,
    ) -> Result<(Matrix, CompressStats)> {
        let h = &self.hyper;
        let hold_end = h.joint.prune_only_iters.clamp(start, h.joint.total_iters);
        let mut best: Option<(f64, Matrix)> = None;
        let mut it = start;
        while it < h.joint.total_iters {
            let joint_phase = it >= hold_end;
            let mut step = if joint_phase {
                h.chunk.max(1).min(h.joint.total_iters - it)
            } else {
                h.chunk.max(1).min(hold_end - it)
            };
            if h.track_series {
                step = 1;
            }
            let rel_loss = if joint_phase {
                let proj = Intersect::new(sparse,
                                          GroupedIntGrid::new(qmax.max(1.0), h.group));
                self.backend.step_chunk(w, c, eta, &proj, step, &mut ws)?.1
            } else {
                self.backend.step_chunk(w, c, eta, &sparse, step, &mut ws)?.1
            };
            it += step;
            if h.track_series {
                series.push(rel_loss);
            }
            if joint_phase {
                Self::track_best(&mut best, rel_loss, ws.theta());
            }
        }
        let theta = match best {
            Some((_, t)) => t,
            None => ws.into_theta(),
        };
        Ok((theta, CompressStats {
            iterations: h.joint.total_iters,
            loss_series: series,
            ..Default::default()
        }))
    }

    /// §4.3 joint: ramp pruning 0→target over 25 iters, prune-only to 50,
    /// then joint prune+quant to 100; best constraint-satisfying iterate.
    ///
    /// Deviation (documented in DESIGN.md §Deviations): the paper leaves the
    /// joint initialisation unspecified. Ramping plain IHT from `Θ=W` makes
    /// the magnitude threshold lock in a *non*-activation-aware mask (the
    /// gradient vanishes at W), which collapses to magnitude-pruning quality.
    /// Consistent with the paper's own §4.1 convention ("initialize Θ(0) as
    /// the solution of Wanda"), the ramp anneals through Wanda solutions at
    /// the scheduled ratio; PGD takes over from iteration 25 exactly as
    /// written. The prune-hold phase routes through the plain row-top-k
    /// projection and the joint phase through the intersection operator —
    /// identical arithmetic to the historical `qmax = 0` switch.
    fn run_joint(&self, w: &Matrix, c: &Matrix, k: usize, qs: QuantSpec)
        -> Result<(Matrix, CompressStats)> {
        let h = &self.hyper;
        let eta = (h.quant_eta_scale / c.frob_norm().max(1e-30)) as f32;
        let mut ws = PgdWorkspace::new(w.clone());
        let mut series = Vec::new();
        // annealed Wanda schedule (activation-aware mask at the ramped k);
        // after the ramp k_at is pinned to the target k
        let ramp = h.joint.ramp_iters.min(h.joint.total_iters);
        for it in 0..ramp {
            ws.install(wanda::wanda_prune(w, c, h.joint.k_at(it, w.cols, k)));
            if h.track_series {
                series.push(Self::rel_loss(w, ws.theta(), c));
            }
        }
        self.run_joint_phases(w, c, eta, qs.qmax(), RowTopK::new(k), ws, ramp, series)
    }

    /// Joint N:M + INT grid (§5 extension of §4.3): the N:M pattern fixes
    /// sparsity at `1 − n/m`, so there is no ratio ramp — the schedule
    /// collapses to the Wanda-N:M init, then the shared hold → joint tail.
    fn run_joint_nm(&self, w: &Matrix, c: &Matrix, n: usize, m: usize,
                    qs: QuantSpec) -> Result<(Matrix, CompressStats)> {
        let h = &self.hyper;
        let eta = (h.quant_eta_scale / c.frob_norm().max(1e-30)) as f32;
        let ws = PgdWorkspace::new(wanda::wanda_prune_nm(w, c, n, m));
        let mut series = Vec::new();
        if h.track_series {
            series.push(Self::rel_loss(w, ws.theta(), c));
        }
        self.run_joint_phases(w, c, eta, qs.qmax(), NmStructured::new(n, m), ws, 0,
                              series)
    }
}

/// bits for a `2^b − 1` qmax (inverse of `QuantSpec::qmax`). Fails loudly
/// on a qmax that is not exactly `2^b − 1` for some `b ∈ 1..=8` — a
/// mismatched `QuantSpec` must error, not silently compress at the nearest
/// in-range bit-width. The HLO backend runs this before handing a qmax
/// scalar to the AOT quant/joint programs (`runtime::hlo_backend`).
pub fn qmax_bits(qmax: f32) -> Result<u8> {
    for b in 1..=8u8 {
        if qmax == ((1u32 << b) - 1) as f32 {
            return Ok(b);
        }
    }
    anyhow::bail!("qmax {qmax} is not 2^b - 1 for any b in 1..=8 — \
                   mismatched QuantSpec?")
}

impl<B: AwpBackend> LayerCompressor for AwpDriver<B> {
    fn name(&self) -> &'static str {
        "awp"
    }

    fn compress(&self, w: &Matrix, c: &Matrix, spec: &CompressionSpec)
        -> Result<CompressedLayer> {
        let t = Timer::start("awp");
        let (theta, partial) = match spec.mode {
            CompressionMode::Prune { .. } => {
                self.run_prune(w, c, spec.keep_k(w.cols).unwrap())?
            }
            CompressionMode::Quant { spec: qs } => {
                assert_eq!(qs.group, self.hyper.group,
                           "quant group must match AOT artifacts");
                self.run_quant(w, c, qs)?
            }
            CompressionMode::Joint { spec: qs, .. } => {
                assert_eq!(qs.group, self.hyper.group);
                self.run_joint(w, c, spec.keep_k(w.cols).unwrap(), qs)?
            }
            CompressionMode::StructuredNm { n, m } => self.run_prune_nm(w, c, n, m)?,
            CompressionMode::JointNm { n, m, spec: qs } => {
                assert_eq!(qs.group, self.hyper.group);
                self.run_joint_nm(w, c, n, m, qs)?
            }
        };
        let mut out = CompressedLayer::from_theta(w, c, theta, partial.iterations,
                                                  t.elapsed_s());
        out.stats.loss_series = partial.loss_series;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_bits_roundtrip() {
        for bits in 1..=8u8 {
            let qmax = ((1u32 << bits) - 1) as f32;
            assert_eq!(qmax_bits(qmax).unwrap(), bits);
        }
    }

    #[test]
    fn qmax_bits_rejects_off_grid_values() {
        for bad in [0.0f32, 2.0, 14.0, 16.0, 254.99, 1000.0] {
            assert!(qmax_bits(bad).is_err(), "qmax {bad} must be rejected");
        }
    }
}
