//! Common interface for layer-wise compressors.

use anyhow::Result;

use crate::proj::{GroupedIntGrid, Intersect, NmStructured, Projection, RowTopK};
use crate::quant::QuantSpec;
use crate::tensor::{ops, Matrix};

/// What to do to a layer. Ratios are *pruning ratios* `p` (fraction of zeros
/// per row), matching the paper's tables; `k = (1-p)·d_in` per eq. (6).
///
/// Each mode names a constraint set; [`CompressionSpec::projection`]
/// resolves it to the [`Projection`] operator the PGD core and the
/// verifier share.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompressionMode {
    /// row-k-sparse (`C_row`, eq. 5)
    Prune { ratio: f64 },
    /// grouped INT grid (`C_INTb`)
    Quant { spec: QuantSpec },
    /// intersection (§4.3)
    Joint { ratio: f64, spec: QuantSpec },
    /// N:M semi-structured sparsity (paper §5 future work, generalised from
    /// NVIDIA's 2:4): at most `n` non-zeros in every aligned group of `m`
    /// along `d_in` (fixed sparsity `1 − n/m`)
    StructuredNm { n: usize, m: usize },
    /// N:M sparsity ∩ INT grid (the §4.3 intersection with a structured
    /// sparsity half)
    JointNm { n: usize, m: usize, spec: QuantSpec },
}

/// A compression request for one layer.
#[derive(Clone, Copy, Debug)]
pub struct CompressionSpec {
    pub mode: CompressionMode,
    pub seed: u64,
}

impl CompressionSpec {
    pub fn prune(ratio: f64) -> Self {
        assert!((0.0..1.0).contains(&ratio));
        CompressionSpec { mode: CompressionMode::Prune { ratio }, seed: 0 }
    }

    pub fn quant(bits: u8, group: usize) -> Self {
        CompressionSpec {
            mode: CompressionMode::Quant { spec: QuantSpec::new(bits, group) },
            seed: 0,
        }
    }

    pub fn joint(ratio: f64, bits: u8, group: usize) -> Self {
        assert!((0.0..1.0).contains(&ratio));
        CompressionSpec {
            mode: CompressionMode::Joint { ratio, spec: QuantSpec::new(bits, group) },
            seed: 0,
        }
    }

    /// per-row kept count for a given `d_in`
    pub fn keep_k(&self, d_in: usize) -> Option<usize> {
        match self.mode {
            CompressionMode::Prune { ratio } | CompressionMode::Joint { ratio, .. } => {
                Some((((1.0 - ratio) * d_in as f64).round() as usize).clamp(1, d_in))
            }
            CompressionMode::Quant { .. }
            | CompressionMode::StructuredNm { .. }
            | CompressionMode::JointNm { .. } => None,
        }
    }

    pub fn quant_spec(&self) -> Option<QuantSpec> {
        match self.mode {
            CompressionMode::Quant { spec }
            | CompressionMode::Joint { spec, .. }
            | CompressionMode::JointNm { spec, .. } => Some(spec),
            CompressionMode::Prune { .. } | CompressionMode::StructuredNm { .. } => None,
        }
    }

    /// N:M at the NVIDIA 2:4 pattern (kept for the §5 ablations).
    pub fn structured24() -> Self {
        CompressionSpec::structured_nm(2, 4)
    }

    pub fn structured_nm(n: usize, m: usize) -> Self {
        assert!(NmStructured::valid(n, m), "N:M needs 1 <= N <= M, got {n}:{m}");
        CompressionSpec { mode: CompressionMode::StructuredNm { n, m }, seed: 0 }
    }

    pub fn joint_nm(n: usize, m: usize, bits: u8, group: usize) -> Self {
        assert!(NmStructured::valid(n, m), "N:M needs 1 <= N <= M, got {n}:{m}");
        CompressionSpec {
            mode: CompressionMode::JointNm { n, m, spec: QuantSpec::new(bits, group) },
            seed: 0,
        }
    }

    /// Content fingerprint of the full spec (mode tag, every mode
    /// parameter, seed) — the spec component of a compressed-artifact key
    /// (`crate::artifact::ArtifactKey`). Artifacts additionally store and
    /// re-validate [`CompressionSpec::describe`], so an FNV collision
    /// degrades to a recompute, never to serving the wrong spec's weights.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::Fnv64::new();
        match self.mode {
            CompressionMode::Prune { ratio } => {
                h.write_usize(0);
                h.write_f64(ratio);
            }
            CompressionMode::Quant { spec } => {
                h.write_usize(1);
                h.write_usize(spec.bits as usize);
                h.write_usize(spec.group);
            }
            CompressionMode::Joint { ratio, spec } => {
                h.write_usize(2);
                h.write_f64(ratio);
                h.write_usize(spec.bits as usize);
                h.write_usize(spec.group);
            }
            CompressionMode::StructuredNm { n, m } => {
                h.write_usize(3);
                h.write_usize(n);
                h.write_usize(m);
            }
            CompressionMode::JointNm { n, m, spec } => {
                h.write_usize(4);
                h.write_usize(n);
                h.write_usize(m);
                h.write_usize(spec.bits as usize);
                h.write_usize(spec.group);
            }
        }
        h.write_u64(self.seed);
        h.finish()
    }

    /// Canonical human-readable form of the spec, stored inside artifacts
    /// for identity re-validation (`Debug` of the mode is stable and
    /// carries every parameter).
    pub fn describe(&self) -> String {
        format!("{:?} seed={}", self.mode, self.seed)
    }

    /// Resolve this spec's constraint set to its projection operator
    /// (`d_in` fixes the per-row keep count). The single resolution the
    /// driver, the verifier ([`check_constraints`]) and the tests share.
    pub fn projection(&self, d_in: usize) -> Box<dyn Projection> {
        match self.mode {
            CompressionMode::Prune { .. } => {
                Box::new(RowTopK::new(self.keep_k(d_in).unwrap()))
            }
            CompressionMode::Quant { spec } => {
                Box::new(GroupedIntGrid::new(spec.qmax(), spec.group))
            }
            CompressionMode::Joint { spec, .. } => Box::new(Intersect::new(
                RowTopK::new(self.keep_k(d_in).unwrap()),
                GroupedIntGrid::new(spec.qmax(), spec.group),
            )),
            CompressionMode::StructuredNm { n, m } => {
                Box::new(NmStructured::new(n, m))
            }
            CompressionMode::JointNm { n, m, spec } => Box::new(Intersect::new(
                NmStructured::new(n, m),
                GroupedIntGrid::new(spec.qmax(), spec.group),
            )),
        }
    }
}

/// Bookkeeping returned with every compressed layer.
#[derive(Clone, Debug, Default)]
pub struct CompressStats {
    /// activation-aware loss ‖(W−Θ)C½‖²_F at the end
    pub final_loss: f64,
    /// ‖(W−Θ)C½‖_F / ‖W‖_F (the Figure-1 metric)
    pub rel_loss: f64,
    /// PGD iterations executed (0 for one-shot methods)
    pub iterations: usize,
    /// wall-clock seconds for this layer
    pub seconds: f64,
    /// optional per-iteration rel-loss series (Figure 1)
    pub loss_series: Vec<f64>,
}

/// Result of compressing one layer.
#[derive(Clone, Debug)]
pub struct CompressedLayer {
    pub theta: Matrix,
    pub stats: CompressStats,
}

impl CompressedLayer {
    pub fn from_theta(w: &Matrix, c: &Matrix, theta: Matrix, iterations: usize,
                      seconds: f64) -> Self {
        let final_loss = ops::activation_loss(w, &theta, c);
        CompressedLayer {
            theta,
            stats: CompressStats {
                final_loss,
                // shared with ops::rel_activation_loss so the artifact
                // eval path recomputes this number bit-for-bit
                rel_loss: ops::rel_loss_from(final_loss, w),
                iterations,
                seconds,
                loss_series: Vec::new(),
            },
        }
    }
}

/// A layer-wise compressor: `(W, C, spec) -> Θ ∈ C`.
pub trait LayerCompressor: Send + Sync {
    fn name(&self) -> &'static str;
    fn compress(&self, w: &Matrix, c: &Matrix, spec: &CompressionSpec)
        -> Result<CompressedLayer>;

    /// Whether `check_constraints`' refit-based INT-grid check applies to
    /// this method's output. False for methods whose grid is *not* the
    /// min/max refit of their own output: AWQ (per-channel-scaled grid) and
    /// GPTQ (grid fitted to the original W, while error compensation moves
    /// group extrema). Their grid membership is asserted by their own unit
    /// tests against their own grid definitions.
    fn grid_refit_checkable(&self) -> bool {
        true
    }
}

/// Which constraint set to re-check on a compressor's output (the
/// pipeline's `verify` pass). The INT-grid refit check only applies to
/// methods whose grid is the min/max fit of their own output (see
/// [`LayerCompressor::grid_refit_checkable`]); for the others, still verify
/// the sparsity half of the spec. `None` ⇒ nothing checkable.
pub fn verification_spec(compressor: &dyn LayerCompressor, spec: &CompressionSpec)
    -> Option<CompressionSpec> {
    if compressor.grid_refit_checkable() {
        return Some(*spec);
    }
    match spec.mode {
        CompressionMode::Prune { .. } | CompressionMode::StructuredNm { .. } => {
            Some(*spec)
        }
        CompressionMode::Joint { ratio, .. } => Some(CompressionSpec::prune(ratio)),
        CompressionMode::JointNm { n, m, .. } => {
            Some(CompressionSpec::structured_nm(n, m))
        }
        CompressionMode::Quant { .. } => None,
    }
}

/// Verify that `theta` satisfies `spec`'s constraint set (used by tests and
/// the coordinator's assembly-time assertions). Routes through
/// [`CompressionSpec::projection`] → [`Projection::check`], so every mode —
/// including new operators — is checked by the same code that projects.
pub fn check_constraints(theta: &Matrix, spec: &CompressionSpec) -> Result<()> {
    spec.projection(theta.cols).check(theta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keep_k_rounding() {
        let s = CompressionSpec::prune(0.5);
        assert_eq!(s.keep_k(64), Some(32));
        let s = CompressionSpec::prune(0.9);
        assert_eq!(s.keep_k(64), Some(6));
        // never zero
        let s = CompressionSpec::prune(0.999);
        assert_eq!(s.keep_k(64), Some(1));
        assert_eq!(CompressionSpec::quant(4, 32).keep_k(64), None);
    }

    #[test]
    fn joint_carries_both() {
        let s = CompressionSpec::joint(0.75, 4, 32);
        assert_eq!(s.keep_k(128), Some(32));
        assert_eq!(s.quant_spec().unwrap().bits, 4);
    }

    #[test]
    fn nm_modes_resolve() {
        let s = CompressionSpec::structured24();
        assert_eq!(s.mode, CompressionMode::StructuredNm { n: 2, m: 4 });
        assert_eq!(s.keep_k(64), None);
        assert!(s.quant_spec().is_none());
        let j = CompressionSpec::joint_nm(4, 8, 4, 32);
        assert_eq!(j.quant_spec().unwrap().bits, 4);
        assert_eq!(j.projection(64).describe(),
                   "nm(4:8) ∩ int-grid(qmax=15, group=32)");
    }

    #[test]
    fn projection_resolution_matches_modes() {
        assert_eq!(CompressionSpec::prune(0.5).projection(64).describe(),
                   "row-topk(k=32)");
        assert_eq!(CompressionSpec::quant(3, 32).projection(64).describe(),
                   "int-grid(qmax=7, group=32)");
        assert_eq!(CompressionSpec::joint(0.75, 2, 16).projection(64).describe(),
                   "row-topk(k=16) ∩ int-grid(qmax=3, group=16)");
        assert_eq!(CompressionSpec::structured_nm(1, 4).projection(64).describe(),
                   "nm(1:4)");
    }

    #[test]
    fn check_constraints_covers_nm_modes() {
        let theta = Matrix::randn(4, 16, 3);
        assert!(check_constraints(&theta, &CompressionSpec::structured24()).is_err());
        let s24 = crate::sparse::project_2_4(&theta);
        check_constraints(&s24, &CompressionSpec::structured24()).unwrap();
        // joint N:M: pattern + grid on the non-zeros
        let spec = CompressionSpec::joint_nm(2, 4, 4, 16);
        assert!(check_constraints(&s24, &spec).is_err());
        let mut both = s24.clone();
        spec.projection(both.cols)
            .project_rows(&mut both, &mut crate::proj::ProjScratch::new());
        check_constraints(&both, &spec).unwrap();
    }

    #[test]
    fn check_constraints_catches_violations() {
        let theta = Matrix::randn(4, 16, 0);
        assert!(check_constraints(&theta, &CompressionSpec::prune(0.5)).is_err());
        let pruned = crate::tensor::topk::hard_threshold_rows(&theta, 8);
        assert!(check_constraints(&pruned, &CompressionSpec::prune(0.5)).is_ok());
        assert!(check_constraints(&theta, &CompressionSpec::quant(4, 16)).is_err());
        let q = crate::quant::quantize_dequantize(&theta, QuantSpec::new(4, 16));
        assert!(check_constraints(&q, &CompressionSpec::quant(4, 16)).is_ok());
    }

    #[test]
    fn verification_spec_respects_refit_checkability() {
        struct NotCheckable;
        impl LayerCompressor for NotCheckable {
            fn name(&self) -> &'static str {
                "nc"
            }
            fn compress(&self, w: &Matrix, c: &Matrix, _spec: &CompressionSpec)
                -> Result<CompressedLayer> {
                Ok(CompressedLayer::from_theta(w, c, w.clone(), 0, 0.0))
            }
            fn grid_refit_checkable(&self) -> bool {
                false
            }
        }
        let nc = NotCheckable;
        // non-checkable grid ⇒ quant check skipped, sparsity half kept
        assert!(verification_spec(&nc, &CompressionSpec::quant(4, 32)).is_none());
        let js = verification_spec(&nc, &CompressionSpec::joint(0.5, 4, 32)).unwrap();
        assert!(matches!(js.mode, CompressionMode::Prune { .. }));
        assert!(verification_spec(&nc, &CompressionSpec::prune(0.5)).is_some());
        // checkable methods re-check the spec as-is
        let m = crate::compress::magnitude::MagnitudePrune;
        let qs = verification_spec(&m, &CompressionSpec::quant(4, 32)).unwrap();
        assert!(matches!(qs.mode, CompressionMode::Quant { .. }));
    }

    #[test]
    fn compressed_layer_stats() {
        let w = Matrix::randn(8, 8, 1);
        let c = Matrix::randn_gram(8, 2);
        let out = CompressedLayer::from_theta(&w, &c, w.clone(), 3, 0.1);
        assert_eq!(out.stats.final_loss, 0.0);
        assert_eq!(out.stats.rel_loss, 0.0);
        assert_eq!(out.stats.iterations, 3);
    }
}
