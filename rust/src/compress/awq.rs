//! AWQ (Lin et al., 2024), re-implemented from scratch.
//!
//! Activation-aware Weight Quantization: per-input-channel scales `s_j`
//! protect salient channels (large activations) from quantization error.
//! `W·diag(s)` is RTN-quantized and `diag(s)⁻¹` is folded back (in real
//! deployments it merges into the previous op; here we fold it into the
//! dequantized weights, which is numerically identical for evaluation).
//!
//! The scale family follows the paper: `s_j = a_j^α` with `a_j` the mean
//! activation magnitude of channel `j` (we use `sqrt(C_jj)`, the RMS), and
//! `α ∈ [0,1]` grid-searched per layer to minimise the *activation-aware*
//! reconstruction loss — the same objective AWQ's official implementation
//! searches with its calibration batch.

use anyhow::{bail, Result};

use super::traits::{CompressedLayer, CompressionMode, CompressionSpec, LayerCompressor};
use crate::quant;
use crate::tensor::{ops, Matrix};
use crate::util::Timer;

pub struct AwqQuant {
    /// α grid resolution (paper uses 20 points)
    pub grid: usize,
}

impl Default for AwqQuant {
    fn default() -> Self {
        AwqQuant { grid: 11 }
    }
}

/// Quantize with channel scales `s`: `Θ = Q(W·diag(s))·diag(s)⁻¹`.
pub fn scaled_rtn(w: &Matrix, s: &[f32], qs: crate::quant::QuantSpec) -> Matrix {
    let scaled = ops::scale_cols(w, s);
    let q = quant::quantize_dequantize(&scaled, qs);
    let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
    ops::scale_cols(&q, &inv)
}

impl LayerCompressor for AwqQuant {
    fn name(&self) -> &'static str {
        "awq"
    }

    fn grid_refit_checkable(&self) -> bool {
        false
    }

    fn compress(&self, w: &Matrix, c: &Matrix, spec: &CompressionSpec)
        -> Result<CompressedLayer> {
        let t = Timer::start("awq");
        let CompressionMode::Quant { spec: qs } = spec.mode else {
            bail!("awq only supports Quant mode (use sequential for combos)");
        };
        // channel activation magnitudes from the Gram diagonal
        let act: Vec<f32> = c
            .diag()
            .iter()
            .map(|&d| d.max(1e-12).sqrt())
            .collect();
        let mut best: Option<(f64, Matrix)> = None;
        for gi in 0..self.grid {
            let alpha = gi as f32 / (self.grid - 1).max(1) as f32;
            let s: Vec<f32> = act
                .iter()
                .map(|&a| a.powf(alpha).clamp(1e-4, 1e4))
                .collect();
            let theta = scaled_rtn(w, &s, qs);
            let loss = ops::activation_loss(w, &theta, c);
            if best.as_ref().map_or(true, |(b, _)| loss < *b) {
                best = Some((loss, theta));
            }
        }
        let (_, theta) = best.unwrap();
        Ok(CompressedLayer::from_theta(w, c, theta, self.grid, t.elapsed_s()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::rtn::RtnQuant;

    #[test]
    fn never_worse_than_rtn() {
        // α = 0 gives s ≡ 1 (exact RTN), so the grid search can only improve
        // the activation-aware loss.
        for seed in 0..5 {
            let w = Matrix::randn(16, 64, seed);
            let c = Matrix::randn_gram(64, 20 + seed);
            let spec = CompressionSpec::quant(3, 32);
            let a = AwqQuant::default().compress(&w, &c, &spec).unwrap();
            let r = RtnQuant.compress(&w, &c, &spec).unwrap();
            assert!(a.stats.final_loss <= r.stats.final_loss * 1.0001,
                    "seed {seed}: {} vs {}", a.stats.final_loss, r.stats.final_loss);
        }
    }

    #[test]
    fn strictly_better_on_outlier_channels() {
        // construct strong activation outliers: AWQ's motivating case
        let w = Matrix::randn(16, 64, 9);
        let mut c = Matrix::randn_gram(64, 10);
        for j in 0..4 {
            let boost = 100.0f32;
            for i in 0..64 {
                *c.at_mut(i, j) *= boost.sqrt();
                *c.at_mut(j, i) *= boost.sqrt();
            }
        }
        let spec = CompressionSpec::quant(3, 32);
        let a = AwqQuant::default().compress(&w, &c, &spec).unwrap();
        let r = RtnQuant.compress(&w, &c, &spec).unwrap();
        assert!(a.stats.final_loss < r.stats.final_loss * 0.95,
                "{} vs {}", a.stats.final_loss, r.stats.final_loss);
    }

    #[test]
    fn scaled_rtn_identity_scales_is_rtn() {
        let w = Matrix::randn(4, 32, 11);
        let qs = crate::quant::QuantSpec::new(4, 32);
        let a = scaled_rtn(&w, &vec![1.0; 32], qs);
        let b = quant::quantize_dequantize(&w, qs);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
