//! Layer-wise compression methods.
//!
//! The paper's contribution (**AWP**, Algorithm 1) plus every baseline its
//! evaluation compares against, all implemented from scratch on the same
//! substrates so the comparisons are apples-to-apples:
//!
//! | method     | paper role                          | module          |
//! |------------|-------------------------------------|-----------------|
//! | AWP        | the contribution (PGD/IHT)          | `awp` (driver), `awp_cpu` (CPU backend), `runtime::hlo_backend` (AOT path) |
//! | Magnitude  | non-activation-aware pruning        | `magnitude`     |
//! | Wanda      | diag(C)-scaled pruning (+AWP init)  | `wanda`         |
//! | SparseGPT  | OBS-based pruning                   | `sparsegpt`     |
//! | RTN        | round-to-nearest quant (+AWP init)  | `rtn`           |
//! | AWQ        | activation-aware scaled quant       | `awq`           |
//! | GPTQ       | OBS-based quant                     | `gptq`          |
//! | AWQ+Wanda, Wanda+AWQ | §4.3 sequential combos    | `sequential`    |
//!
//! Every method implements [`traits::LayerCompressor`]: given `(W, C, spec)`
//! produce a compressed `Θ` in the constraint set plus bookkeeping stats.

pub mod awp;
pub mod awp_cpu;
pub mod awq;
pub mod gptq;
pub mod magnitude;
pub mod obs;
pub mod rtn;
pub mod schedule;
pub mod sequential;
pub mod sparsegpt;
pub mod traits;
pub mod wanda;

pub use awp::{AwpBackend, AwpDriver, AwpHyper};
pub use awp_cpu::{AwpCpu, CpuBackend};
pub use traits::{
    CompressStats, CompressedLayer, CompressionMode, CompressionSpec, LayerCompressor,
};
