//! Linear-site dispatch — every matmul in the native forward pass routes
//! through [`LinearOp`], which either runs the dense GEMM over an f32
//! matrix or the packed kernels straight off a [`PreparedPacked`]
//! (streaming dequant / survivor-only sparse on the reference tier,
//! compressed-domain SIMD kernels on the fast tier — see
//! [`crate::tensor::KernelTier`] and KERNELS.md). The packed variants
//! never materialise a dense Θ, and [`LinearOp::apply_tier`] runs out of a
//! per-thread workspace so both tiers are allocation-free after warm-up
//! (modulo the returned activation matrix).

use std::cell::RefCell;
use std::sync::Arc;

use anyhow::Result;

use crate::artifact::{ArtifactPager, PackedLinear, PreparedPacked};
use crate::obs::metrics;
use crate::tensor::{ops, KernelTier, Matrix};

/// One linear site's weights, as the forward pass sees them: a borrowed
/// view that the model's math dispatches on per call.
#[derive(Debug)]
pub enum LinearOp<'a> {
    /// Dense f32 `(d_out, d_in)` — the assembled-checkpoint path.
    Dense(&'a Matrix),
    /// Bit-packed site straight from a compressed artifact, with its
    /// decode offsets precomputed — executed by the packed GEMMs, never
    /// decoded to a dense matrix.
    Packed(&'a PreparedPacked),
}

impl LinearOp<'_> {
    pub fn d_out(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.rows,
            LinearOp::Packed(p) => p.rows(),
        }
    }

    pub fn d_in(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.cols,
            LinearOp::Packed(p) => p.cols(),
        }
    }

    /// `W · B` on the reference tier: the dense row-panel GEMM
    /// ([`ops::matmul`]), the streaming dequant GEMM or the survivor-only
    /// sparse GEMM. All three share the dense kernel's blocking and
    /// accumulation order, so on bit-identical weights every variant
    /// produces bit-identical output — the invariant
    /// `rust/tests/native_forward.rs` pins end-to-end.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        self.matmul_tier(b, KernelTier::Reference)
    }

    /// `W · B` on the selected [`KernelTier`] ([`PreparedPacked`] holds the
    /// per-variant dispatch; the fast tier is tolerance-validated, not
    /// bitwise — KERNELS.md).
    pub fn matmul_tier(&self, b: &Matrix, tier: KernelTier) -> Matrix {
        match self {
            // dense launches are timed here; packed launches are timed at
            // their own dispatch (`PreparedPacked::matmul_tier_into`), so
            // every site launch is counted exactly once
            LinearOp::Dense(w) => {
                let t = metrics::timer();
                let out = ops::matmul_tier(w, b, tier);
                metrics::observe_kernel(matches!(tier, KernelTier::Fast), t);
                out
            }
            LinearOp::Packed(p) => p.matmul_tier(b, tier),
        }
    }

    /// Activation-side application `X · Wᵀ` for row-major activations
    /// `x: (tokens, d_in)` → `(tokens, d_out)`, computed as `(W · Xᵀ)ᵀ` so
    /// both representations run the same `W · B` kernels (and therefore
    /// stay bit-identical to each other on the reference tier).
    ///
    /// The activation rows become B *columns*, and every kernel on either
    /// tier accumulates each output element over `k` in an order that does
    /// not depend on how many columns ride along — so each row of the
    /// result is bit-identical whether it is applied alone or stacked with
    /// other rows (pinned below). That row-count invariance is what lets
    /// [`crate::infer::NativeModel::decode_step_batch`] fuse many sessions'
    /// decode steps into one launch without changing any session's bits,
    /// while the packed fast kernels amortise their per-launch hoisted work
    /// (group column sums, survivor lists, palette LUTs) over the batch.
    pub fn apply(&self, x: &Matrix) -> Matrix {
        self.apply_tier(x, KernelTier::Reference)
    }

    /// [`LinearOp::apply`] on the selected tier. The `Xᵀ` staging buffer
    /// and the `W·Xᵀ` product live in a per-thread workspace (grown once,
    /// reused across calls — same discipline as `proj::PgdWorkspace`), so
    /// the only per-call allocation on either tier is the returned
    /// activation matrix; the packed kernels' decode scratch is per-thread
    /// too (`artifact::packed`).
    pub fn apply_tier(&self, x: &Matrix, tier: KernelTier) -> Matrix {
        thread_local! {
            static APPLY_SCRATCH: RefCell<(Matrix, Matrix)> =
                RefCell::new((Matrix::zeros(0, 0), Matrix::zeros(0, 0)));
        }
        APPLY_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let (xt, wxt) = &mut *scratch;
            x.transpose_into(xt);
            match self {
                LinearOp::Dense(w) => {
                    let t = metrics::timer();
                    ops::matmul_tier_into(w, xt, tier, wxt);
                    metrics::observe_kernel(matches!(tier, KernelTier::Fast), t);
                }
                LinearOp::Packed(p) => p.matmul_tier_into(xt, tier, wxt),
            }
            wxt.transpose()
        })
    }
}

/// Owned storage behind a [`LinearOp`] — the
/// [`NativeModel`](super::NativeModel) site table.
pub enum SiteWeights {
    Dense(Matrix),
    Packed(PreparedPacked),
    /// Lazily paged site: the weights live in the pager's residency
    /// cache (or on disk) and are resolved per application — this is the
    /// variant that lets serving run artifacts larger than RAM.
    Paged(Arc<ArtifactPager>, usize),
}

impl std::fmt::Debug for SiteWeights {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SiteWeights::Dense(m) => write!(f, "Dense({}x{})", m.rows, m.cols),
            SiteWeights::Packed(p) => {
                write!(f, "Packed({}x{} {})", p.rows(), p.cols(), p.mode_name())
            }
            SiteWeights::Paged(pg, i) => {
                let m = &pg.sites()[*i];
                write!(f, "Paged({}x{} {} @{})", m.rows, m.cols, m.mode, m.param)
            }
        }
    }
}

impl SiteWeights {
    /// Wrap a freshly decoded packed payload, preparing its decode
    /// offsets once.
    pub fn packed(p: PackedLinear) -> SiteWeights {
        SiteWeights::Packed(p.prepare())
    }

    /// Site `idx` of `pager`, resolved lazily on each application.
    pub fn paged(pager: Arc<ArtifactPager>, idx: usize) -> SiteWeights {
        SiteWeights::Paged(pager, idx)
    }

    /// Output width — header metadata for paged sites, so no page-in.
    pub fn d_out(&self) -> usize {
        match self {
            SiteWeights::Dense(m) => m.rows,
            SiteWeights::Packed(p) => p.rows(),
            SiteWeights::Paged(pg, i) => pg.sites()[*i].rows,
        }
    }

    /// Input width — header metadata for paged sites, so no page-in.
    pub fn d_in(&self) -> usize {
        match self {
            SiteWeights::Dense(m) => m.cols,
            SiteWeights::Packed(p) => p.cols(),
            SiteWeights::Paged(pg, i) => pg.sites()[*i].cols,
        }
    }

    /// [`LinearOp::apply_tier`] over this site's weights, resolving
    /// paged sites through their pager first — the only fallible step
    /// (I/O + first-touch validation), which is why this returns
    /// `Result` while the borrowed [`LinearOp`] stays infallible.
    pub fn apply_tier(&self, x: &Matrix, tier: KernelTier) -> Result<Matrix> {
        match self {
            SiteWeights::Dense(m) => Ok(LinearOp::Dense(m).apply_tier(x, tier)),
            SiteWeights::Packed(p) => Ok(LinearOp::Packed(p).apply_tier(x, tier)),
            SiteWeights::Paged(pg, i) => {
                let p = pg.site(*i)?;
                Ok(LinearOp::Packed(&p).apply_tier(x, tier))
            }
        }
    }

    /// Reference-tier [`SiteWeights::apply_tier`].
    pub fn apply(&self, x: &Matrix) -> Result<Matrix> {
        self.apply_tier(x, KernelTier::Reference)
    }

    /// `true` when the site executes through the packed kernels (paged
    /// sites always do — the pager only hands out [`PreparedPacked`]).
    pub fn is_packed(&self) -> bool {
        matches!(self, SiteWeights::Packed(_) | SiteWeights::Paged(..))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::traits::CompressionSpec;
    use crate::proj::{NmStructured, ProjScratch, Projection};
    use crate::quant::project_qmax;

    fn assert_bits_eq(a: &Matrix, b: &Matrix) {
        assert_eq!(a.shape(), b.shape());
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "entry {i}: {x} vs {y}");
        }
    }

    #[test]
    fn dense_and_packed_apply_agree_bitwise() {
        let x = Matrix::randn(9, 64, 7);
        // quantized site → streaming dequant path
        let theta = project_qmax(&Matrix::randn(16, 64, 0), 15.0, 32);
        let packed = PackedLinear::encode(&theta, &CompressionSpec::quant(4, 32))
            .prepare();
        assert_eq!(packed.mode_name(), "int");
        assert_bits_eq(&LinearOp::Dense(&theta).apply(&x),
                       &LinearOp::Packed(&packed).apply(&x));
        // N:M site → survivor-only sparse path
        let mut nm = Matrix::randn(16, 64, 1);
        NmStructured::new(2, 4).project_rows(&mut nm, &mut ProjScratch::new());
        let packed = PackedLinear::encode(&nm, &CompressionSpec::structured_nm(2, 4))
            .prepare();
        assert_eq!(packed.mode_name(), "mask");
        assert_bits_eq(&LinearOp::Dense(&nm).apply(&x),
                       &LinearOp::Packed(&packed).apply(&x));
    }

    #[test]
    fn fast_apply_matches_reference_within_tol() {
        let x = Matrix::randn(9, 64, 17);
        let theta = project_qmax(&Matrix::randn(16, 64, 18), 15.0, 32);
        let packed = PackedLinear::encode(&theta, &CompressionSpec::quant(4, 32))
            .prepare();
        let op = LinearOp::Packed(&packed);
        let fast = op.apply_tier(&x, KernelTier::Fast);
        let reference = op.apply(&x);
        assert_eq!(fast.shape(), reference.shape());
        for (i, (a, b)) in fast.data.iter().zip(&reference.data).enumerate() {
            let tol = 1e-4 * (1.0 + a.abs() + b.abs());
            assert!((a - b).abs() <= tol, "entry {i}: {a} vs {b}");
        }
    }

    #[test]
    fn apply_rows_are_batch_width_invariant() {
        // each activation row's output is bit-identical whether applied
        // alone or stacked with others — the invariance decode_step_batch
        // rides on (dense, int-packed and mask-packed sites, both tiers)
        let x = Matrix::randn(6, 64, 23);
        let theta = project_qmax(&Matrix::randn(16, 64, 24), 15.0, 32);
        let int_packed =
            PackedLinear::encode(&theta, &CompressionSpec::quant(4, 32)).prepare();
        let mut nm = Matrix::randn(16, 64, 25);
        NmStructured::new(2, 4).project_rows(&mut nm, &mut ProjScratch::new());
        let nm_packed =
            PackedLinear::encode(&nm, &CompressionSpec::structured_nm(2, 4))
                .prepare();
        let ops: [LinearOp<'_>; 3] = [
            LinearOp::Dense(&theta),
            LinearOp::Packed(&int_packed),
            LinearOp::Packed(&nm_packed),
        ];
        for op in &ops {
            // reference tier: exact — the k-accumulation order per output
            // element never looks at the column count
            let stacked = op.apply(&x);
            // fast tier: lane/tail split depends on the width, so batched
            // rows are pinned to the reference answer by tolerance instead
            let stacked_fast = op.apply_tier(&x, KernelTier::Fast);
            for i in 0..x.rows {
                let mut single = Matrix::zeros(1, x.cols);
                single.row_mut(0).copy_from_slice(x.row(i));
                let alone = op.apply(&single);
                for (a, b) in alone.row(0).iter().zip(stacked.row(i)) {
                    assert_eq!(a.to_bits(), b.to_bits(),
                               "row {i} changed bits when batched");
                }
                for (a, b) in alone.row(0).iter().zip(stacked_fast.row(i)) {
                    let tol = 1e-4 * (1.0 + a.abs() + b.abs());
                    assert!((a - b).abs() <= tol,
                            "fast row {i}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn apply_reuses_workspace_and_stays_correct_across_shapes() {
        // same thread, alternating shapes: the workspace must resize
        // correctly and never leak one call's values into the next
        let w1 = Matrix::randn(5, 12, 2);
        let w2 = Matrix::randn(7, 9, 3);
        for round in 0..3u64 {
            let x1 = Matrix::randn(4, 12, 10 + round);
            let got = LinearOp::Dense(&w1).apply(&x1);
            assert_bits_eq(&got, &ops::matmul(&w1, &x1.transpose()).transpose());
            let x2 = Matrix::randn(6, 9, 20 + round);
            let got = LinearOp::Dense(&w2).apply(&x2);
            assert_bits_eq(&got, &ops::matmul(&w2, &x2.transpose()).transpose());
        }
    }

    #[test]
    fn apply_shapes_and_dims() {
        let w = Matrix::randn(5, 12, 2);
        let op = LinearOp::Dense(&w);
        assert_eq!((op.d_out(), op.d_in()), (5, 12));
        let x = Matrix::randn(3, 12, 3);
        assert_eq!(op.apply(&x).shape(), (3, 5));
    }

    #[test]
    fn site_weights_report_packing() {
        let w = Matrix::randn(4, 32, 5);
        assert!(!SiteWeights::Dense(w.clone()).is_packed());
        let p = PackedLinear::encode(&w, &CompressionSpec::prune(0.5));
        assert!(SiteWeights::packed(p).is_packed());
    }
}
