//! Linear-site dispatch — every matmul in the native forward pass routes
//! through [`LinearOp`], which either runs the dense row-panel GEMM over an
//! f32 matrix or the packed kernels straight off a [`PackedLinear`]
//! (streaming dequant for int/palette/dense payloads, survivor-only sparse
//! GEMM for masks). The packed variants never materialise a dense Θ.

use crate::artifact::PackedLinear;
use crate::tensor::{ops, Matrix};

/// One linear site's weights, as the forward pass sees them: a borrowed
/// view that the model's math dispatches on per call.
#[derive(Debug)]
pub enum LinearOp<'a> {
    /// Dense f32 `(d_out, d_in)` — the assembled-checkpoint path.
    Dense(&'a Matrix),
    /// Bit-packed site straight from a compressed artifact — executed by
    /// the packed GEMMs, never decoded to a dense matrix.
    Packed(&'a PackedLinear),
}

impl LinearOp<'_> {
    pub fn d_out(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.rows,
            LinearOp::Packed(p) => p.rows(),
        }
    }

    pub fn d_in(&self) -> usize {
        match self {
            LinearOp::Dense(w) => w.cols,
            LinearOp::Packed(p) => p.cols(),
        }
    }

    /// `W · B`, dispatched to the dense row-panel GEMM
    /// ([`ops::matmul`]), the streaming dequant GEMM
    /// ([`PackedLinear::matmul`]) or the survivor-only sparse GEMM
    /// ([`PackedLinear::matmul_sparse`]). All three share the dense
    /// kernel's blocking and accumulation order, so on bit-identical
    /// weights every variant produces bit-identical output — the invariant
    /// `rust/tests/native_forward.rs` pins end-to-end.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        match self {
            LinearOp::Dense(w) => ops::matmul(w, b),
            LinearOp::Packed(p) => match p {
                // mask sites take the survivor-only kernel: fully pruned
                // quads cost nothing — the N:M payoff, inside the model
                PackedLinear::SparseMask { .. } => p.matmul_sparse(b),
                _ => p.matmul(b),
            },
        }
    }

    /// Activation-side application `X · Wᵀ` for row-major activations
    /// `x: (tokens, d_in)` → `(tokens, d_out)`, computed as `(W · Xᵀ)ᵀ` so
    /// both representations run the same `W · B` kernels (and therefore
    /// stay bit-identical to each other).
    pub fn apply(&self, x: &Matrix) -> Matrix {
        let xt = x.transpose();
        self.matmul(&xt).transpose()
    }
}

/// Owned storage behind a [`LinearOp`] — the
/// [`NativeModel`](super::NativeModel) site table.
#[derive(Debug)]
pub enum SiteWeights {
    Dense(Matrix),
    Packed(PackedLinear),
}

impl SiteWeights {
    pub fn op(&self) -> LinearOp<'_> {
        match self {
            SiteWeights::Dense(m) => LinearOp::Dense(m),
            SiteWeights::Packed(p) => LinearOp::Packed(p),
        }
    }

    /// `true` when the site executes through the packed kernels.
    pub fn is_packed(&self) -> bool {
        matches!(self, SiteWeights::Packed(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::traits::CompressionSpec;
    use crate::proj::{NmStructured, ProjScratch, Projection};
    use crate::quant::project_qmax;

    fn assert_bits_eq(a: &Matrix, b: &Matrix) {
        assert_eq!(a.shape(), b.shape());
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "entry {i}: {x} vs {y}");
        }
    }

    #[test]
    fn dense_and_packed_apply_agree_bitwise() {
        let x = Matrix::randn(9, 64, 7);
        // quantized site → streaming dequant path
        let theta = project_qmax(&Matrix::randn(16, 64, 0), 15.0, 32);
        let packed = PackedLinear::encode(&theta, &CompressionSpec::quant(4, 32));
        assert_eq!(packed.mode_name(), "int");
        assert_bits_eq(&LinearOp::Dense(&theta).apply(&x),
                       &LinearOp::Packed(&packed).apply(&x));
        // N:M site → survivor-only sparse path
        let mut nm = Matrix::randn(16, 64, 1);
        NmStructured::new(2, 4).project_rows(&mut nm, &mut ProjScratch::new());
        let packed = PackedLinear::encode(&nm, &CompressionSpec::structured_nm(2, 4));
        assert_eq!(packed.mode_name(), "mask");
        assert_bits_eq(&LinearOp::Dense(&nm).apply(&x),
                       &LinearOp::Packed(&packed).apply(&x));
    }

    #[test]
    fn apply_shapes_and_dims() {
        let w = Matrix::randn(5, 12, 2);
        let op = LinearOp::Dense(&w);
        assert_eq!((op.d_out(), op.d_in()), (5, 12));
        let x = Matrix::randn(3, 12, 3);
        assert_eq!(op.apply(&x).shape(), (3, 5));
    }

    #[test]
    fn site_weights_report_packing() {
        let w = Matrix::randn(4, 32, 5);
        assert!(!SiteWeights::Dense(w.clone()).is_packed());
        let p = PackedLinear::encode(&w, &CompressionSpec::prune(0.5));
        assert!(SiteWeights::Packed(p).is_packed());
    }
}
