//! The native CPU transformer forward pass — the Rust mirror of
//! `python/compile/model.py::forward` (pre-norm blocks, RoPE causal
//! attention, SiLU MLP, tied embedding head), with every block-linear site
//! dispatched through [`SiteWeights`].
//!
//! ### The packed ≡ dense contract
//!
//! [`NativeModel::from_checkpoint`] (all sites dense f32) and
//! [`NativeModel::from_artifact`] (all sites packed) run the *same* code:
//! the only difference is which [`SiteWeights`] variant each site matmul
//! dispatches to, and those variants are bit-identical to each other on
//! bit-identical weights (shared row-panel kernel — see
//! `artifact::packed`). Everything around the site matmuls (norms, RoPE,
//! attention, softmax, NLL) is computed once per output element in a fixed
//! sequential order, and the parallel primitives only split *independent*
//! units (rows, `(batch, head)` blocks), so logits are also deterministic
//! across thread budgets. Together: packed logits ≡ dense logits ≡ the
//! same bits at any `AWP_THREADS` (`rust/tests/native_forward.rs`).
//!
//! ### KV-cached incremental decode
//!
//! [`DecodeSession`] holds per-block post-RoPE K/V rows plus the next RoPE
//! position, so generation pays O(ctx) per new token instead of re-running
//! the O(ctx²) full window. [`NativeModel::prefill`] pushes a batch of
//! tokens through every block once (appending their K/V rows),
//! [`NativeModel::decode_step`] is the one-token case. At the
//! [`KernelTier::Reference`] tier the cached path is **bit-identical** to
//! [`NativeModel::forward`] over the same prefix: every reference GEMM
//! accumulates each output element over `k` in a fixed order that does not
//! depend on how many activation columns ride along, RMSNorm/RoPE/SiLU are
//! row-local, and `cached_attention` replays `causal_attention`'s exact
//! per-position dot/softmax/mix sequence against the cached rows
//! (`rust/tests/serve_decode.rs` pins this differentially). The fast tier
//! stays within the KERNELS.md tolerance, as for the full forward.
//!
//! ### Batched decode across sessions
//!
//! [`NativeModel::decode_step_batch`] fuses one decode step of many
//! sessions into a single forward: the new tokens stack into one
//! `(batch, d_model)` activation matrix, so every linear site and the tied
//! head run **once** per step and the packed fast kernels amortise their
//! per-launch work (group column sums, survivor lists, palette LUTs) over
//! the whole batch — the serving-throughput lever `serve::DecodeBatcher`
//! schedules onto. The batch is *ragged*: each session keeps its own RoPE
//! position and its own K/V cache, and attention stays per-session. The
//! same argument as above makes the batched step bit-identical per session
//! to serial [`NativeModel::decode_step`] at the reference tier: reference
//! GEMMs accumulate each output element over `k` in a fixed order that is
//! invariant to how many activation rows ride along, every non-GEMM op is
//! row-local, and the per-row attention replays `cached_attention`'s exact
//! dot/softmax/mix sequence over that session's own cache.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::artifact::{ArtifactPager, ModelArtifact};
use crate::model::{sites, Checkpoint, ModelConfig};
use crate::obs::trace;
use crate::tensor::{ops, KernelTier, Matrix};
use crate::util::parallel::{par_chunks_mut, par_map};

use super::linear::SiteWeights;

/// Sites per transformer block, in [`sites::enumerate_sites`] order
/// (wq, wk, wv, wo, w_up, w_down).
const SITES_PER_BLOCK: usize = 6;

/// Per-session decode state: one post-RoPE K buffer and one V buffer per
/// transformer block (each `(capacity, d_model)`, rows `..len()` valid)
/// plus the next RoPE position. Create with [`NativeModel::new_session`],
/// grow with [`NativeModel::prefill`] / [`NativeModel::decode_step`]. The
/// session owns no weights — it is pure context state, cheap to hold per
/// connection in a server.
#[derive(Debug)]
pub struct DecodeSession {
    /// Per-layer cached key rows (RoPE already applied).
    k: Vec<Matrix>,
    /// Per-layer cached value rows.
    v: Vec<Matrix>,
    /// Cached positions; also the RoPE offset of the next token.
    len: usize,
    /// Fixed context window this session was allocated for.
    capacity: usize,
}

impl DecodeSession {
    /// Positions cached so far — the RoPE offset the next token gets.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum context length this session can hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Positions left before the session is full.
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    /// Forget the cached context, keeping the allocated buffers.
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Resident size of the K/V buffers in bytes (the LRU eviction
    /// accounting unit in `serve::SessionStore`).
    pub fn kv_bytes(&self) -> usize {
        self.k
            .iter()
            .chain(&self.v)
            .map(|m| m.data.len() * std::mem::size_of::<f32>())
            .sum()
    }
}

/// A transformer LM ready to run on the CPU: embeddings and norms held
/// dense (they are never compressed), block-linear sites held as
/// [`SiteWeights`] — dense f32 or bit-packed.
#[derive(Debug)]
pub struct NativeModel {
    cfg: ModelConfig,
    embed: Matrix,
    ln1: Vec<Vec<f32>>,
    ln2: Vec<Vec<f32>>,
    ln_f: Vec<f32>,
    /// `n_layers × 6` sites in [`sites::enumerate_sites`] order
    site_weights: Vec<SiteWeights>,
    /// Which GEMM tier every site matmul (and the tied head) runs on.
    /// Defaults to [`KernelTier::Reference`] — the bit-identical oracle;
    /// [`NativeModel::set_tier`] switches serving onto the fast kernels.
    tier: KernelTier,
}

impl NativeModel {
    /// Build a model from non-site tensors of `ck` plus explicit per-site
    /// weights (`(param name, weights)`, any order). Every compressible
    /// site of `ck.config` must appear exactly once with matching shape —
    /// the constructor the dense/packed entry points and the differential
    /// tests share.
    pub fn with_site_weights(ck: &Checkpoint,
                             site_weights: Vec<(String, SiteWeights)>)
        -> Result<NativeModel> {
        let cfg = ck.config.clone();
        ensure!(cfg.n_heads >= 1 && cfg.d_model % cfg.n_heads == 0,
                "d_model {} not divisible by n_heads {}", cfg.d_model, cfg.n_heads);
        ensure!((cfg.d_model / cfg.n_heads) % 2 == 0,
                "RoPE needs an even head_dim, got {}", cfg.d_model / cfg.n_heads);
        let embed = ck.matrix("embed")?;
        ensure!(embed.shape() == (cfg.vocab, cfg.d_model),
                "embed shape {:?} != ({}, {})", embed.shape(), cfg.vocab,
                cfg.d_model);
        let mut by_name: HashMap<String, SiteWeights> = HashMap::new();
        for (name, w) in site_weights {
            ensure!(by_name.insert(name.clone(), w).is_none(),
                    "duplicate site weights for {name}");
        }
        let mut ordered = Vec::new();
        for s in sites::enumerate_sites(&cfg) {
            let w = by_name
                .remove(&s.param)
                .with_context(|| format!("native model missing site {}", s.param))?;
            let (rows, cols) = (w.d_out(), w.d_in());
            ensure!((rows, cols) == (s.d_out, s.d_in),
                    "site {}: weights are {}x{}, expected {}x{}", s.param, rows,
                    cols, s.d_out, s.d_in);
            ordered.push(w);
        }
        if let Some(extra) = by_name.keys().next() {
            anyhow::bail!("unexpected site weights for {extra}");
        }
        let norm = |name: &str| -> Result<Vec<f32>> {
            let (shape, data) = ck
                .get(name)
                .with_context(|| format!("tensor {name} not in checkpoint"))?;
            ensure!(shape == [cfg.d_model].as_slice(), "{name} shape {shape:?}");
            Ok(data.to_vec())
        };
        let mut ln1 = Vec::with_capacity(cfg.n_layers);
        let mut ln2 = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            ln1.push(norm(&format!("blocks.{l}.ln1"))?);
            ln2.push(norm(&format!("blocks.{l}.ln2"))?);
        }
        let ln_f = norm("ln_f")?;
        Ok(NativeModel {
            cfg,
            embed,
            ln1,
            ln2,
            ln_f,
            site_weights: ordered,
            tier: KernelTier::Reference,
        })
    }

    /// All-dense native model over an assembled checkpoint — the reference
    /// side of the differential harness and the `repro eval --native`
    /// checkpoint path.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<NativeModel> {
        let mut sw = Vec::new();
        for s in sites::enumerate_sites(&ck.config) {
            sw.push((s.param.clone(), SiteWeights::Dense(ck.matrix(&s.param)?)));
        }
        Self::with_site_weights(ck, sw)
    }

    /// Packed native model: every block-linear site comes straight from
    /// the artifact **in packed form** (the `PackedLinear` payload is
    /// cloned, never decoded — zero f32 weight assembly on this route);
    /// embeddings and norms come from the base checkpoint, which the
    /// compression pipeline leaves untouched. Identity (checkpoint/calib
    /// fingerprints) is the caller's concern, as in the assembled
    /// `eval --from-artifact` path.
    pub fn from_artifact(ck: &Checkpoint, art: &ModelArtifact) -> Result<NativeModel> {
        let mut sw = Vec::new();
        for s in sites::enumerate_sites(&ck.config) {
            let site = art
                .sites
                .iter()
                .find(|a| a.param == s.param)
                .with_context(|| format!("artifact misses site {}", s.param))?;
            sw.push((s.param.clone(), SiteWeights::packed(site.packed.clone())));
        }
        Self::with_site_weights(ck, sw)
    }

    /// Paged native model over an open [`ArtifactPager`]: every
    /// block-linear site is a lazy [`SiteWeights::Paged`] handle that
    /// materialises from the artifact file on first touch and may be
    /// evicted again under the pager's byte budget. Shapes are validated
    /// against the artifact **header** alone — construction reads zero
    /// payload bytes, so cold open is O(header) no matter how large the
    /// artifact is.
    pub fn from_pager(ck: &Checkpoint, pager: Arc<ArtifactPager>)
        -> Result<NativeModel> {
        let mut sw = Vec::new();
        for s in sites::enumerate_sites(&ck.config) {
            let idx = pager
                .sites()
                .iter()
                .position(|m| m.param == s.param)
                .with_context(|| format!("artifact misses site {}", s.param))?;
            sw.push((s.param.clone(), SiteWeights::paged(pager.clone(), idx)));
        }
        Self::with_site_weights(ck, sw)
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Select the GEMM tier the forward pass runs on
    /// ([`KernelTier::Reference`] by default). The fast tier changes
    /// accumulation order/FMA only — logits stay within the documented
    /// tolerance of the reference tier (KERNELS.md) and remain
    /// deterministic across thread budgets.
    pub fn set_tier(&mut self, tier: KernelTier) {
        self.tier = tier;
    }

    pub fn tier(&self) -> KernelTier {
        self.tier
    }

    /// Sites executing through the packed kernels.
    pub fn packed_site_count(&self) -> usize {
        self.site_weights.iter().filter(|w| w.is_packed()).count()
    }

    /// Sites materialised as dense f32 matrices. Zero on the
    /// [`NativeModel::from_artifact`] route — the number the CLI logs as
    /// "decode-to-dense assemblies" and the CI smoke pins at 0.
    pub fn dense_site_count(&self) -> usize {
        self.site_weights.len() - self.packed_site_count()
    }

    fn site(&self, layer: usize, slot: usize) -> &SiteWeights {
        &self.site_weights[layer * SITES_PER_BLOCK + slot]
    }

    /// Full forward pass over a row-major `(batch, seq)` token block;
    /// returns logits `(batch·seq, vocab)`.
    pub fn forward(&self, tokens: &[i32], batch: usize, seq: usize)
        -> Result<Matrix> {
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let dh = d / nh;
        ensure!(batch >= 1 && seq >= 1, "empty forward geometry");
        ensure!(tokens.len() == batch * seq,
                "token block {} != {batch}x{seq}", tokens.len());
        let t = batch * seq;
        let mut x = Matrix::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            ensure!(tok >= 0 && (tok as usize) < self.cfg.vocab,
                    "token {tok} outside vocab {}", self.cfg.vocab);
            x.row_mut(i).copy_from_slice(self.embed.row(tok as usize));
        }
        let (cos, sin) = rope_tables(seq, dh, self.cfg.rope_theta);
        for l in 0..self.cfg.n_layers {
            // attention half: pre-norm, q/k/v, RoPE, causal softmax, out
            let h = rmsnorm(&x, &self.ln1[l]);
            let mut q = self.site(l, 0).apply_tier(&h, self.tier)?;
            let mut k = self.site(l, 1).apply_tier(&h, self.tier)?;
            let v = self.site(l, 2).apply_tier(&h, self.tier)?;
            rope_rows(&mut q, seq, nh, dh, &cos, &sin);
            rope_rows(&mut k, seq, nh, dh, &cos, &sin);
            let o = causal_attention(&q, &k, &v, batch, seq, nh, dh);
            let o = self.site(l, 3).apply_tier(&o, self.tier)?;
            add_inplace(&mut x, &o);
            // MLP half: pre-norm, up, SiLU, down
            let h = rmsnorm(&x, &self.ln2[l]);
            let mut u = self.site(l, 4).apply_tier(&h, self.tier)?;
            silu_inplace(&mut u);
            let down = self.site(l, 5).apply_tier(&u, self.tier)?;
            add_inplace(&mut x, &down);
        }
        let xf = rmsnorm(&x, &self.ln_f);
        // tied head: logits = Xf · Eᵀ, as (E · Xfᵀ)ᵀ on the tier's kernel
        Ok(ops::matmul_tier(&self.embed, &xf.transpose(), self.tier).transpose())
    }

    /// Summed next-token NLL plus predicted-token count over a `(batch,
    /// seq)` block — the `eval_loss` program's contract (targets are
    /// `tokens[:, 1:]`).
    pub fn nll(&self, tokens: &[i32], batch: usize, seq: usize)
        -> Result<(f64, usize)> {
        ensure!(seq >= 2, "nll needs seq >= 2");
        let logits = self.forward(tokens, batch, seq)?;
        // one independent unit per predicted position; par_map returns in
        // index order and each unit is sequential, so the reduction is
        // deterministic at any thread budget
        let nlls = par_map(batch * (seq - 1), |p| {
            let (bi, si) = (p / (seq - 1), p % (seq - 1));
            let row = logits.row(bi * seq + si);
            let tgt = tokens[bi * seq + si + 1] as usize;
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f64;
            for &l in row {
                denom += ((l - m) as f64).exp();
            }
            (m as f64 + denom.ln()) - row[tgt] as f64
        });
        Ok((nlls.into_iter().sum(), batch * (seq - 1)))
    }

    /// Last-position logits of a `(1, len)` context, computed through a
    /// throwaway [`DecodeSession`]. One-shot callers get the same bits as
    /// `forward(ctx, 1, len)`'s last row (pinned by test); loops that decode
    /// token-by-token should hold their own session and call
    /// [`NativeModel::decode_step`] instead.
    pub fn logits_last(&self, ctx: &[i32]) -> Result<Vec<f32>> {
        ensure!(!ctx.is_empty(), "decode context must be non-empty");
        let mut session = self.new_session(ctx.len());
        self.prefill(&mut session, ctx)
    }

    /// Allocate a [`DecodeSession`] holding up to `capacity` positions of
    /// per-block K/V state for this model.
    pub fn new_session(&self, capacity: usize) -> DecodeSession {
        let capacity = capacity.max(1);
        let d = self.cfg.d_model;
        let alloc = || {
            (0..self.cfg.n_layers)
                .map(|_| Matrix::zeros(capacity, d))
                .collect()
        };
        DecodeSession { k: alloc(), v: alloc(), len: 0, capacity }
    }

    /// Push `tokens` through the model in one batched pass, appending their
    /// K/V rows to `session`, and return the logits of the **last** new
    /// position. The first call plays the prompt (prefill); later calls
    /// extend the same context, so `prefill(a); prefill(b)` ≡
    /// `prefill(a ++ b)` and — at the reference tier — ≡ the last row of
    /// `forward(a ++ b)`, bitwise.
    pub fn prefill(&self, session: &mut DecodeSession, tokens: &[i32])
        -> Result<Vec<f32>> {
        // covers decode_step too (it delegates here); arg formatting is
        // skipped entirely while the span sink is off
        let mut _span = trace::span("prefill", "infer");
        if trace::enabled() {
            _span.set_arg("tokens", tokens.len().to_string());
        }
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let dh = d / nh;
        ensure!(session.k.len() == self.cfg.n_layers
                    && session.k.iter().all(|m| m.cols == d),
                "decode session does not fit this model");
        let seq = tokens.len();
        ensure!(seq >= 1, "prefill needs at least one token");
        let start = session.len;
        ensure!(start + seq <= session.capacity,
                "decode session full: {start} cached + {seq} new > capacity {}",
                session.capacity);
        let mut x = Matrix::zeros(seq, d);
        for (i, &tok) in tokens.iter().enumerate() {
            ensure!(tok >= 0 && (tok as usize) < self.cfg.vocab,
                    "token {tok} outside vocab {}", self.cfg.vocab);
            x.row_mut(i).copy_from_slice(self.embed.row(tok as usize));
        }
        // rotation tables for absolute positions start..start+seq — same
        // bits as rows start.. of the full-window tables
        let (cos, sin) = rope_tables_from(start, seq, dh, self.cfg.rope_theta);
        for l in 0..self.cfg.n_layers {
            let h = rmsnorm(&x, &self.ln1[l]);
            let mut q = self.site(l, 0).apply_tier(&h, self.tier)?;
            let mut k = self.site(l, 1).apply_tier(&h, self.tier)?;
            let v = self.site(l, 2).apply_tier(&h, self.tier)?;
            rope_rows(&mut q, seq, nh, dh, &cos, &sin);
            rope_rows(&mut k, seq, nh, dh, &cos, &sin);
            for i in 0..seq {
                session.k[l].row_mut(start + i).copy_from_slice(k.row(i));
                session.v[l].row_mut(start + i).copy_from_slice(v.row(i));
            }
            let o = cached_attention(&q, &session.k[l], &session.v[l], start,
                                     seq, nh, dh);
            let o = self.site(l, 3).apply_tier(&o, self.tier)?;
            add_inplace(&mut x, &o);
            let h = rmsnorm(&x, &self.ln2[l]);
            let mut u = self.site(l, 4).apply_tier(&h, self.tier)?;
            silu_inplace(&mut u);
            let down = self.site(l, 5).apply_tier(&u, self.tier)?;
            add_inplace(&mut x, &down);
        }
        session.len = start + seq;
        // final norm + tied head for the last new position only
        let mut last = Matrix::zeros(1, d);
        last.row_mut(0).copy_from_slice(x.row(seq - 1));
        let xf = rmsnorm(&last, &self.ln_f);
        let logits =
            ops::matmul_tier(&self.embed, &xf.transpose(), self.tier).transpose();
        Ok(logits.row(0).to_vec())
    }

    /// Incremental decode: append one token to the cached context and return
    /// its logits — O(ctx) per call where the full-window forward is
    /// O(ctx²) per generated token.
    pub fn decode_step(&self, session: &mut DecodeSession, token: i32)
        -> Result<Vec<f32>> {
        self.prefill(session, &[token])
    }

    /// One fused decode step over a **ragged batch** of sessions: token
    /// `tokens[i]` is appended to `sessions[i]` (each at its own position)
    /// and the per-session logits come back in order. Every linear site and
    /// the tied head see the whole `(batch, d_model)` activation stack in
    /// one launch, so the packed fast kernels amortise their hoisted decode
    /// work across the batch; RoPE rotates each row at its own session's
    /// absolute position and attention runs per session over that session's
    /// own cache. At the reference tier every session's logits are
    /// **bit-identical** to a serial [`NativeModel::decode_step`] on that
    /// session alone (see the module docs for the argument;
    /// `rust/tests/serve_decode.rs` pins it for ragged batches across
    /// thread budgets). Aliased sessions are unrepresentable — `&mut`
    /// exclusivity means one session cannot appear twice in the slice.
    ///
    /// Validation happens entirely up front: on `Err` no session has been
    /// touched.
    pub fn decode_step_batch(&self, sessions: &mut [&mut DecodeSession],
                             tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        let mut _span = trace::span("decode_step_batch", "infer");
        if trace::enabled() {
            _span.set_arg("batch", sessions.len().to_string());
        }
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let dh = d / nh;
        let n = sessions.len();
        ensure!(n >= 1, "decode batch is empty");
        ensure!(tokens.len() == n,
                "decode batch: {} tokens for {n} sessions", tokens.len());
        for (i, s) in sessions.iter().enumerate() {
            ensure!(s.k.len() == self.cfg.n_layers
                        && s.k.iter().all(|m| m.cols == d),
                    "decode session {i} does not fit this model");
            ensure!(s.len < s.capacity,
                    "decode session {i} full: {} cached + 1 new > capacity {}",
                    s.len, s.capacity);
        }
        let mut x = Matrix::zeros(n, d);
        for (i, &tok) in tokens.iter().enumerate() {
            ensure!(tok >= 0 && (tok as usize) < self.cfg.vocab,
                    "token {tok} outside vocab {}", self.cfg.vocab);
            x.row_mut(i).copy_from_slice(self.embed.row(tok as usize));
        }
        let starts: Vec<usize> = sessions.iter().map(|s| s.len).collect();
        // one table row per session, each at that session's own absolute
        // position — row i is bit-identical to the row the session's serial
        // step would build via rope_tables_from(starts[i], 1, ..)
        let (cos, sin) = rope_tables_at(&starts, dh, self.cfg.rope_theta);
        for l in 0..self.cfg.n_layers {
            let h = rmsnorm(&x, &self.ln1[l]);
            let mut q = self.site(l, 0).apply_tier(&h, self.tier)?;
            let mut k = self.site(l, 1).apply_tier(&h, self.tier)?;
            let v = self.site(l, 2).apply_tier(&h, self.tier)?;
            // with seq = n, rope_rows maps activation row i onto table row i
            rope_rows(&mut q, n, nh, dh, &cos, &sin);
            rope_rows(&mut k, n, nh, dh, &cos, &sin);
            for (i, s) in sessions.iter_mut().enumerate() {
                s.k[l].row_mut(starts[i]).copy_from_slice(k.row(i));
                s.v[l].row_mut(starts[i]).copy_from_slice(v.row(i));
            }
            let caches: Vec<(&Matrix, &Matrix, usize)> = sessions
                .iter()
                .zip(&starts)
                .map(|(s, &pos)| (&s.k[l], &s.v[l], pos))
                .collect();
            let o = cached_attention_rows(&q, &caches, nh, dh);
            let o = self.site(l, 3).apply_tier(&o, self.tier)?;
            add_inplace(&mut x, &o);
            let h = rmsnorm(&x, &self.ln2[l]);
            let mut u = self.site(l, 4).apply_tier(&h, self.tier)?;
            silu_inplace(&mut u);
            let down = self.site(l, 5).apply_tier(&u, self.tier)?;
            add_inplace(&mut x, &down);
        }
        for s in sessions.iter_mut() {
            s.len += 1;
        }
        let xf = rmsnorm(&x, &self.ln_f);
        let logits =
            ops::matmul_tier(&self.embed, &xf.transpose(), self.tier).transpose();
        Ok((0..n).map(|i| logits.row(i).to_vec()).collect())
    }
}

// ---------------------------------------------------------------------------
// forward-pass math (free functions so the pieces unit-test in isolation)

/// Row-wise RMSNorm `x · g · rsqrt(mean(x²) + 1e-6)` (the jax `_rmsnorm`).
fn rmsnorm(x: &Matrix, g: &[f32]) -> Matrix {
    let d = x.cols;
    debug_assert_eq!(g.len(), d);
    let mut out = Matrix::zeros(x.rows, d);
    let src = &x.data;
    par_chunks_mut(&mut out.data, d, |i, orow| {
        let row = &src[i * d..(i + 1) * d];
        let ms = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            / d as f64;
        let r = (1.0 / (ms + 1e-6).sqrt()) as f32;
        for j in 0..d {
            orow[j] = row[j] * g[j] * r;
        }
    });
    out
}

fn add_inplace(x: &mut Matrix, y: &Matrix) {
    debug_assert_eq!(x.shape(), y.shape());
    for (a, b) in x.data.iter_mut().zip(&y.data) {
        *a += b;
    }
}

/// `v ← v · sigmoid(v)` (the jax `jax.nn.silu`).
fn silu_inplace(u: &mut Matrix) {
    for v in u.data.iter_mut() {
        *v /= 1.0 + (-*v).exp();
    }
}

/// Per-(position, frequency) rotation tables, `(seq × dh/2)` each.
fn rope_tables(seq: usize, dh: usize, theta: f64) -> (Vec<f32>, Vec<f32>) {
    rope_tables_from(0, seq, dh, theta)
}

/// Rotation tables for absolute positions `start..start + seq`. Each row is
/// a pure function of the absolute position, so the table for position `p`
/// is bit-identical whether built from 0 or from any offset — the property
/// that lets an incremental decode step agree with the full window.
fn rope_tables_from(start: usize, seq: usize, dh: usize, theta: f64)
    -> (Vec<f32>, Vec<f32>) {
    let half = dh / 2;
    let mut cos = Vec::with_capacity(seq * half);
    let mut sin = Vec::with_capacity(seq * half);
    for s in start..start + seq {
        for c in 0..half {
            let freq = theta.powf(-(c as f64) / half as f64);
            let ang = (s as f64 * freq) as f32;
            cos.push(ang.cos());
            sin.push(ang.sin());
        }
    }
    (cos, sin)
}

/// Rotation tables for an arbitrary list of absolute positions — output
/// row `i` covers `positions[i]`. Each row evaluates the same per-position
/// expression as [`rope_tables_from`], so a ragged batch of sessions at
/// different offsets sees rotations bit-identical to the rows each
/// session's own serial step would build.
fn rope_tables_at(positions: &[usize], dh: usize, theta: f64)
    -> (Vec<f32>, Vec<f32>) {
    let half = dh / 2;
    let mut cos = Vec::with_capacity(positions.len() * half);
    let mut sin = Vec::with_capacity(positions.len() * half);
    for &s in positions {
        for c in 0..half {
            let freq = theta.powf(-(c as f64) / half as f64);
            let ang = (s as f64 * freq) as f32;
            cos.push(ang.cos());
            sin.push(ang.sin());
        }
    }
    (cos, sin)
}

/// Apply RoPE in place over `(batch·seq, nh·dh)` rows (split-half rotation,
/// matching the jax `_rope`). Row `i`'s position is `i % seq`.
fn rope_rows(x: &mut Matrix, seq: usize, nh: usize, dh: usize, cos: &[f32],
             sin: &[f32]) {
    let half = dh / 2;
    let d = x.cols;
    debug_assert_eq!(d, nh * dh);
    par_chunks_mut(&mut x.data, d, |i, row| {
        let si = i % seq;
        let (ct, st) = (&cos[si * half..(si + 1) * half],
                        &sin[si * half..(si + 1) * half]);
        for h in 0..nh {
            let base = h * dh;
            for c in 0..half {
                let x1 = row[base + c];
                let x2 = row[base + half + c];
                row[base + c] = x1 * ct[c] - x2 * st[c];
                row[base + half + c] = x1 * st[c] + x2 * ct[c];
            }
        }
    });
}

/// Causal softmax attention over `(batch·seq, nh·dh)` q/k/v blocks. One
/// independent unit per `(batch, head)`; within a unit every position is
/// processed sequentially, so the output is thread-count invariant.
fn causal_attention(q: &Matrix, k: &Matrix, v: &Matrix, batch: usize,
                    seq: usize, nh: usize, dh: usize) -> Matrix {
    let d = nh * dh;
    let inv = 1.0 / (dh as f32).sqrt();
    let blocks = par_map(batch * nh, |bh| {
        let (bi, h) = (bh / nh, bh % nh);
        let col = h * dh;
        let mut out = vec![0.0f32; seq * dh];
        let mut scores = vec![0.0f32; seq];
        for si in 0..seq {
            let qrow = &q.row(bi * seq + si)[col..col + dh];
            for sj in 0..=si {
                let krow = &k.row(bi * seq + sj)[col..col + dh];
                let mut dot = 0.0f32;
                for c in 0..dh {
                    dot += qrow[c] * krow[c];
                }
                scores[sj] = dot * inv;
            }
            let m = scores[..=si]
                .iter()
                .cloned()
                .fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f32;
            for s in scores[..=si].iter_mut() {
                *s = (*s - m).exp();
                denom += *s;
            }
            let o = &mut out[si * dh..(si + 1) * dh];
            for sj in 0..=si {
                let p = scores[sj] / denom;
                let vrow = &v.row(bi * seq + sj)[col..col + dh];
                for c in 0..dh {
                    o[c] += p * vrow[c];
                }
            }
        }
        out
    });
    let mut o = Matrix::zeros(batch * seq, d);
    for (bh, block) in blocks.iter().enumerate() {
        let (bi, h) = (bh / nh, bh % nh);
        for si in 0..seq {
            o.row_mut(bi * seq + si)[h * dh..(h + 1) * dh]
                .copy_from_slice(&block[si * dh..(si + 1) * dh]);
        }
    }
    o
}

/// Causal attention of `seq` fresh query rows (absolute positions
/// `start..start + seq`) against the cached K/V rows `0..start + seq` — the
/// KV-cache counterpart of `causal_attention` (batch is always 1). For each
/// query position it runs the *same* dot/softmax/mix sequence over the same
/// key range in the same order, so given cache rows identical to the
/// full-window K/V it produces bit-identical output rows.
fn cached_attention(q: &Matrix, kc: &Matrix, vc: &Matrix, start: usize,
                    seq: usize, nh: usize, dh: usize) -> Matrix {
    let d = nh * dh;
    let inv = 1.0 / (dh as f32).sqrt();
    let total = start + seq;
    let blocks = par_map(nh, |h| {
        let col = h * dh;
        let mut out = vec![0.0f32; seq * dh];
        let mut scores = vec![0.0f32; total];
        for si in 0..seq {
            let pos = start + si;
            let qrow = &q.row(si)[col..col + dh];
            for sj in 0..=pos {
                let krow = &kc.row(sj)[col..col + dh];
                let mut dot = 0.0f32;
                for c in 0..dh {
                    dot += qrow[c] * krow[c];
                }
                scores[sj] = dot * inv;
            }
            let m = scores[..=pos]
                .iter()
                .cloned()
                .fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f32;
            for s in scores[..=pos].iter_mut() {
                *s = (*s - m).exp();
                denom += *s;
            }
            let o = &mut out[si * dh..(si + 1) * dh];
            for sj in 0..=pos {
                let p = scores[sj] / denom;
                let vrow = &vc.row(sj)[col..col + dh];
                for c in 0..dh {
                    o[c] += p * vrow[c];
                }
            }
        }
        out
    });
    let mut o = Matrix::zeros(seq, d);
    for (h, block) in blocks.iter().enumerate() {
        for si in 0..seq {
            o.row_mut(si)[h * dh..(h + 1) * dh]
                .copy_from_slice(&block[si * dh..(si + 1) * dh]);
        }
    }
    o
}

/// One decode step of attention over a ragged batch: query row `i`
/// (absolute position `caches[i].2`) attends over its own session's cached
/// K/V rows `0..=pos`. One independent unit per `(session, head)`; within
/// a unit the dot/softmax/mix sequence is exactly [`cached_attention`]'s
/// `seq = 1` body, so every output row is bit-identical to the one that
/// session's serial decode step computes — and thread-count invariant,
/// since `par_map` only splits across the independent units.
fn cached_attention_rows(q: &Matrix, caches: &[(&Matrix, &Matrix, usize)],
                         nh: usize, dh: usize) -> Matrix {
    let n = caches.len();
    let d = nh * dh;
    let inv = 1.0 / (dh as f32).sqrt();
    let blocks = par_map(n * nh, |u| {
        let (i, h) = (u / nh, u % nh);
        let (kc, vc, pos) = caches[i];
        let col = h * dh;
        let mut out = vec![0.0f32; dh];
        let mut scores = vec![0.0f32; pos + 1];
        let qrow = &q.row(i)[col..col + dh];
        for (sj, score) in scores.iter_mut().enumerate() {
            let krow = &kc.row(sj)[col..col + dh];
            let mut dot = 0.0f32;
            for c in 0..dh {
                dot += qrow[c] * krow[c];
            }
            *score = dot * inv;
        }
        let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            denom += *s;
        }
        for sj in 0..=pos {
            let p = scores[sj] / denom;
            let vrow = &vc.row(sj)[col..col + dh];
            for c in 0..dh {
                out[c] += p * vrow[c];
            }
        }
        out
    });
    let mut o = Matrix::zeros(n, d);
    for (u, block) in blocks.iter().enumerate() {
        let (i, h) = (u / nh, u % nh);
        o.row_mut(i)[h * dh..(h + 1) * dh].copy_from_slice(block);
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trainer::init_checkpoint;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(), vocab: 32, d_model: 16, n_heads: 2, n_layers: 2,
            d_ff: 24, seq_len: 8, batch: 1, decode_len: 8, rope_theta: 1e4,
        }
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let ck = init_checkpoint(&cfg(), 3);
        let m = NativeModel::from_checkpoint(&ck).unwrap();
        assert_eq!(m.dense_site_count(), 12);
        assert_eq!(m.packed_site_count(), 0);
        let tokens: Vec<i32> = (0..16).map(|i| (i * 5 % 32) as i32).collect();
        let logits = m.forward(&tokens, 2, 8).unwrap();
        assert_eq!(logits.shape(), (16, 32));
        assert!(logits.data.iter().all(|v| v.is_finite()));
        let (nll, count) = m.nll(&tokens, 2, 8).unwrap();
        assert!(nll.is_finite() && nll > 0.0);
        assert_eq!(count, 14);
    }

    #[test]
    fn fast_tier_logits_match_reference_within_tol() {
        let ck = init_checkpoint(&cfg(), 7);
        let reference = NativeModel::from_checkpoint(&ck).unwrap();
        let mut fast = NativeModel::from_checkpoint(&ck).unwrap();
        assert_eq!(fast.tier(), KernelTier::Reference);
        fast.set_tier(KernelTier::Fast);
        let tokens: Vec<i32> = (0..16).map(|i| (i * 5 % 32) as i32).collect();
        let a = reference.forward(&tokens, 2, 8).unwrap();
        let b = fast.forward(&tokens, 2, 8).unwrap();
        assert_eq!(a.shape(), b.shape());
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            let tol = 1e-4 * (1.0 + x.abs() + y.abs());
            assert!((x - y).abs() <= tol, "logit {i}: {x} vs {y}");
        }
    }

    #[test]
    fn forward_is_causal() {
        // changing a future token must not change earlier positions' logits
        let ck = init_checkpoint(&cfg(), 4);
        let m = NativeModel::from_checkpoint(&ck).unwrap();
        let mut tokens: Vec<i32> = (0..8).map(|i| (i * 3 % 32) as i32).collect();
        let a = m.forward(&tokens, 1, 8).unwrap();
        tokens[7] = (tokens[7] + 1) % 32;
        let b = m.forward(&tokens, 1, 8).unwrap();
        for i in 0..7 {
            for (x, y) in a.row(i).iter().zip(b.row(i)) {
                assert_eq!(x.to_bits(), y.to_bits(), "position {i} leaked");
            }
        }
        assert_ne!(a.row(7), b.row(7), "last position must see its own token");
    }

    #[test]
    fn batch_rows_are_independent() {
        let ck = init_checkpoint(&cfg(), 5);
        let m = NativeModel::from_checkpoint(&ck).unwrap();
        let row: Vec<i32> = (0..8).map(|i| (i * 7 % 32) as i32).collect();
        let single = m.forward(&row, 1, 8).unwrap();
        let mut two = row.clone();
        two.extend((0..8).map(|i| (i * 11 % 32) as i32));
        let both = m.forward(&two, 2, 8).unwrap();
        for i in 0..8 {
            for (x, y) in single.row(i).iter().zip(both.row(i)) {
                assert_eq!(x.to_bits(), y.to_bits(), "batch row 0 diverged");
            }
        }
    }

    #[test]
    fn logits_last_matches_forward() {
        let ck = init_checkpoint(&cfg(), 6);
        let m = NativeModel::from_checkpoint(&ck).unwrap();
        let ctx: Vec<i32> = (0..6).map(|i| (i % 32) as i32).collect();
        let last = m.logits_last(&ctx).unwrap();
        let full = m.forward(&ctx, 1, 6).unwrap();
        assert_eq!(last, full.row(5));
    }

    #[test]
    fn decode_steps_match_full_window_bitwise() {
        let ck = init_checkpoint(&cfg(), 8);
        let m = NativeModel::from_checkpoint(&ck).unwrap();
        let tokens: Vec<i32> = (0..8).map(|i| (i * 13 % 32) as i32).collect();
        let mut sess = m.new_session(tokens.len());
        let mut cached = vec![m.prefill(&mut sess, &tokens[..1]).unwrap()];
        for &t in &tokens[1..] {
            cached.push(m.decode_step(&mut sess, t).unwrap());
        }
        assert_eq!(sess.len(), tokens.len());
        assert_eq!(sess.remaining(), 0);
        for (i, got) in cached.iter().enumerate() {
            let full = m.forward(&tokens[..=i], 1, i + 1).unwrap();
            for (a, b) in got.iter().zip(full.row(i)) {
                assert_eq!(a.to_bits(), b.to_bits(), "position {i} diverged");
            }
        }
    }

    #[test]
    fn batched_decode_step_matches_serial_bitwise() {
        let ck = init_checkpoint(&cfg(), 21);
        let m = NativeModel::from_checkpoint(&ck).unwrap();
        // ragged batch: three sessions prefilled to different positions
        let prompts: [&[i32]; 3] = [&[1, 2, 3], &[4], &[5, 6, 7, 8, 9]];
        let mut serial: Vec<DecodeSession> = Vec::new();
        let mut batched: Vec<DecodeSession> = Vec::new();
        for p in prompts {
            let mut a = m.new_session(16);
            m.prefill(&mut a, p).unwrap();
            serial.push(a);
            let mut b = m.new_session(16);
            m.prefill(&mut b, p).unwrap();
            batched.push(b);
        }
        let steps: [[i32; 3]; 2] = [[10, 11, 12], [13, 14, 15]];
        for toks in steps {
            let want: Vec<Vec<f32>> = serial
                .iter_mut()
                .zip(toks)
                .map(|(s, t)| m.decode_step(s, t).unwrap())
                .collect();
            let mut refs: Vec<&mut DecodeSession> =
                batched.iter_mut().collect();
            let got = m.decode_step_batch(&mut refs, &toks).unwrap();
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                for (a, b) in w.iter().zip(g) {
                    assert_eq!(a.to_bits(), b.to_bits(), "session {i} diverged");
                }
            }
        }
        for (s, b) in serial.iter().zip(&batched) {
            assert_eq!(s.len(), b.len());
        }
    }

    #[test]
    fn batched_decode_validates_without_mutating() {
        let ck = init_checkpoint(&cfg(), 22);
        let m = NativeModel::from_checkpoint(&ck).unwrap();
        let mut a = m.new_session(4);
        m.prefill(&mut a, &[1, 2, 3, 4]).unwrap(); // full
        let mut b = m.new_session(4);
        m.prefill(&mut b, &[1]).unwrap();
        let mut refs = vec![&mut a, &mut b];
        let err = m.decode_step_batch(&mut refs, &[5, 6]).unwrap_err();
        assert!(format!("{err:#}").contains("full"));
        assert_eq!((a.len(), b.len()), (4, 1), "failed batch must not advance");
        // geometry errors
        let mut c = m.new_session(4);
        assert!(m.decode_step_batch(&mut [], &[]).is_err());
        assert!(m.decode_step_batch(&mut [&mut c], &[1, 2]).is_err());
        assert!(m.decode_step_batch(&mut [&mut c], &[99]).is_err());
    }

    #[test]
    fn chunked_prefill_equals_one_shot() {
        let ck = init_checkpoint(&cfg(), 9);
        let m = NativeModel::from_checkpoint(&ck).unwrap();
        let tokens: Vec<i32> = (0..7).map(|i| (i * 9 % 32) as i32).collect();
        let one_shot = m.logits_last(&tokens).unwrap();
        let mut sess = m.new_session(16);
        m.prefill(&mut sess, &tokens[..3]).unwrap();
        let chunked = m.prefill(&mut sess, &tokens[3..]).unwrap();
        assert_eq!(sess.len(), 7);
        for (a, b) in one_shot.iter().zip(&chunked) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn session_capacity_and_reset() {
        let ck = init_checkpoint(&cfg(), 10);
        let m = NativeModel::from_checkpoint(&ck).unwrap();
        let mut sess = m.new_session(4);
        assert!(sess.is_empty());
        assert!(sess.kv_bytes() > 0);
        m.prefill(&mut sess, &[1, 2, 3]).unwrap();
        let err = m.prefill(&mut sess, &[4, 5]).unwrap_err();
        assert!(format!("{err:#}").contains("decode session full"));
        assert_eq!(sess.len(), 3, "failed prefill must not advance");
        sess.reset();
        assert_eq!(sess.remaining(), 4);
        let after_reset = m.prefill(&mut sess, &[1, 2, 3]).unwrap();
        let fresh = m.logits_last(&[1, 2, 3]).unwrap();
        assert_eq!(after_reset, fresh);
        // a session sized for a different model is rejected
        let mut other_cfg = cfg();
        other_cfg.d_model = 32;
        let other = NativeModel::from_checkpoint(
            &init_checkpoint(&other_cfg, 10)).unwrap();
        let mut foreign = other.new_session(4);
        assert!(m.prefill(&mut foreign, &[1]).is_err());
    }

    #[test]
    fn construction_validates_inputs() {
        let ck = init_checkpoint(&cfg(), 0);
        // missing site
        let err = NativeModel::with_site_weights(&ck, Vec::new());
        assert!(format!("{:#}", err.unwrap_err()).contains("missing site"));
        // out-of-vocab token
        let m = NativeModel::from_checkpoint(&ck).unwrap();
        assert!(m.forward(&[99], 1, 1).is_err());
        assert!(m.forward(&[-1], 1, 1).is_err());
        // geometry mismatch
        assert!(m.forward(&[0, 1, 2], 2, 2).is_err());
        // odd head_dim rejected
        let mut bad = cfg();
        bad.d_model = 6; // 6 / 2 heads = 3, odd
        let bad_ck = init_checkpoint(&bad, 0);
        assert!(NativeModel::from_checkpoint(&bad_ck).is_err());
    }

    #[test]
    fn rmsnorm_matches_definition() {
        let x = Matrix::randn(3, 8, 9);
        let g: Vec<f32> = (0..8).map(|i| 1.0 + 0.1 * i as f32).collect();
        let out = rmsnorm(&x, &g);
        for i in 0..3 {
            let ms: f64 = x.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / 8.0;
            let r = (1.0 / (ms + 1e-6).sqrt()) as f32;
            for j in 0..8 {
                assert_eq!(out.at(i, j).to_bits(), (x.at(i, j) * g[j] * r).to_bits());
            }
        }
    }

    #[test]
    fn rope_preserves_pair_norms() {
        // a rotation: each (x1, x2) pair keeps its magnitude (approximately)
        let mut x = Matrix::randn(4, 16, 11); // seq 4, 2 heads × dh 8
        let before = x.clone();
        let (cos, sin) = rope_tables(4, 8, 1e4);
        rope_rows(&mut x, 4, 2, 8, &cos, &sin);
        for i in 0..4 {
            for h in 0..2 {
                for c in 0..4 {
                    let (a1, a2) = (before.at(i, h * 8 + c), before.at(i, h * 8 + 4 + c));
                    let (b1, b2) = (x.at(i, h * 8 + c), x.at(i, h * 8 + 4 + c));
                    let na = (a1 * a1 + a2 * a2).sqrt();
                    let nb = (b1 * b1 + b2 * b2).sqrt();
                    assert!((na - nb).abs() < 1e-4, "{na} vs {nb}");
                }
            }
        }
        // position 0 is the identity rotation
        assert_eq!(x.row(0), before.row(0));
    }

    #[test]
    fn attention_rows_sum_to_convex_combination() {
        // with v = all-ones, any softmax-weighted average is exactly ~1
        let q = Matrix::randn(6, 8, 12);
        let k = Matrix::randn(6, 8, 13);
        let v = Matrix::from_fn(6, 8, |_, _| 1.0);
        let o = causal_attention(&q, &k, &v, 1, 6, 2, 4);
        for val in &o.data {
            assert!((val - 1.0).abs() < 1e-5, "{val}");
        }
    }
}
