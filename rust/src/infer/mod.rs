//! Native packed-inference engine — a pure-CPU transformer forward pass in
//! which every linear site dispatches through [`LinearOp`]: either a dense
//! f32 matrix or a bit-packed [`crate::artifact::PackedLinear`] executed by
//! the streaming-dequant / survivor-only GEMMs of
//! [`crate::artifact::packed`]. A compressed artifact *serves* here without
//! ever being assembled back into a dense f32 checkpoint — the packed
//! representation is the execution format, not just the storage format.
//!
//! Two entry points build the same [`NativeModel`]:
//!
//! * [`NativeModel::from_checkpoint`] — all sites dense (the reference
//!   path, `repro eval --native`);
//! * [`NativeModel::from_artifact`] — all sites packed, zero
//!   decode-to-dense assemblies (`repro eval --native --from-artifact`).
//!
//! Because the two paths differ only in which GEMM variant each site
//! matmul dispatches to, and those variants share the dense kernel's
//! accumulation order (`tensor::ops::matmul_row_panel`), packed and dense
//! logits/perplexity are **bit-identical** — the contract
//! `rust/tests/native_forward.rs` and the CI native-eval smoke pin.
//!
//! That bit-identity statement is the **reference tier**. The model also
//! carries a [`crate::tensor::KernelTier`] ([`NativeModel::set_tier`],
//! CLI `--fast`, env `AWP_KERNEL_TIER`): the *fast* tier swaps every site
//! matmul and the tied head onto compressed-domain + SIMD kernels
//! (integer-accumulate GEMM for int sites, cache-blocked survivor-only
//! GEMM for masks, palette-LUT GEMM, AVX2/FMA row panels) that are
//! tolerance-validated against the reference tier rather than bitwise —
//! bounds and policy in KERNELS.md, differential coverage in
//! `rust/tests/fast_kernels.rs`.
//!
//! Parallelism (GEMM row panels, attention `(batch, head)` blocks,
//! per-position NLL) runs under the `AWP_THREADS` budget via
//! [`crate::util::parallel`] and is thread-count invariant on *both*
//! tiers (each output row is computed sequentially by one worker).
//!
//! Incremental decode rides on [`DecodeSession`] — per-block K/V caches
//! plus the RoPE position offset — so generation and `repro serve` pay
//! O(ctx) per token ([`NativeModel::prefill`] /
//! [`NativeModel::decode_step`]), bit-identical to the full-window
//! forward at the reference tier (`rust/tests/serve_decode.rs`).

pub mod linear;
pub mod model;

pub use linear::{LinearOp, SiteWeights};
pub use model::{DecodeSession, NativeModel};
