//! N:M semi-structured sparsity — the generalisation of the paper's §5
//! future-work 2:4 pattern: in every aligned group of `m` consecutive
//! weights along `d_in`, at most `n` are non-zero.
//!
//! `NmStructured::new(2, 4)` is bit-identical to
//! [`crate::sparse::project_2_4`] on `d_in % 4 == 0` inputs (pinned in
//! `rust/tests/proj_laws.rs`); unlike that reference it also handles tail
//! groups (`d_in % m != 0`), keeping `min(n, tail)` entries there.

use anyhow::{bail, Result};

use super::{ProjKind, ProjScratch, Projection};
use crate::tensor::Matrix;

/// Keep the `n` largest-|.| entries of every aligned `m`-group per row.
/// Ties are broken by column order (stable sort), matching `project_2_4`.
#[derive(Clone, Copy, Debug)]
pub struct NmStructured {
    n: usize,
    m: usize,
}

impl NmStructured {
    /// The one N:M validity rule every construction path shares
    /// (spec constructors, CLI parsing, this type's own `new`).
    pub fn valid(n: usize, m: usize) -> bool {
        n >= 1 && m >= 2 && n <= m
    }

    pub fn new(n: usize, m: usize) -> Self {
        assert!(Self::valid(n, m), "N:M needs 1 <= N <= M, M >= 2; got {n}:{m}");
        NmStructured { n, m }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn m(&self) -> usize {
        self.m
    }
}

impl Projection for NmStructured {
    fn name(&self) -> &'static str {
        "nm"
    }

    fn describe(&self) -> String {
        format!("nm({}:{})", self.n, self.m)
    }

    fn project_rows(&self, z: &mut Matrix, scratch: &mut ProjScratch) {
        let (rows, cols) = z.shape();
        for i in 0..rows {
            let row = &mut z.data[i * cols..(i + 1) * cols];
            for g in (0..cols).step_by(self.m) {
                let end = (g + self.m).min(cols);
                let quad = &mut row[g..end];
                if quad.len() <= self.n {
                    continue; // tail shorter than n: nothing to drop
                }
                let idx = scratch.idx(quad.len());
                for (t, s) in idx.iter_mut().enumerate() {
                    *s = t;
                }
                // stable descending-|.| sort: ties keep column order,
                // exactly like project_2_4's index sort
                idx.sort_by(|&a, &b| {
                    quad[b].abs().partial_cmp(&quad[a].abs()).unwrap()
                });
                for &j in &idx[self.n..] {
                    quad[j] = 0.0;
                }
            }
        }
    }

    fn check(&self, theta: &Matrix) -> Result<()> {
        for i in 0..theta.rows {
            let row = theta.row(i);
            for g in (0..theta.cols).step_by(self.m) {
                let end = (g + self.m).min(theta.cols);
                let nnz = row[g..end].iter().filter(|&&v| v != 0.0).count();
                if nnz > self.n {
                    bail!("row {i} group at col {g}: {nnz} nonzeros violate \
                           the {}:{} pattern", self.n, self.m);
                }
            }
        }
        Ok(())
    }

    fn kind(&self) -> ProjKind<'_> {
        ProjKind::Nm { n: self.n, m: self.m }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse;

    #[test]
    fn two_four_matches_reference() {
        for seed in 0..6u64 {
            let z = Matrix::randn(5, 32, seed);
            let want = sparse::project_2_4(&z);
            let mut got = z.clone();
            NmStructured::new(2, 4).project_rows(&mut got, &mut ProjScratch::new());
            assert_eq!(got.data, want.data, "seed={seed}");
            assert!(sparse::check_2_4(&got));
        }
    }

    #[test]
    fn four_eight_halves_density() {
        let z = Matrix::randn(6, 64, 3);
        let mut p = z.clone();
        let nm = NmStructured::new(4, 8);
        nm.project_rows(&mut p, &mut ProjScratch::new());
        nm.check(&p).unwrap();
        assert!((p.sparsity() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn tail_group_keeps_at_most_n() {
        // cols = 10 with m = 4: groups [0..4), [4..8), tail [8..10)
        let z = Matrix::randn(3, 10, 7);
        let mut p = z.clone();
        let nm = NmStructured::new(1, 4);
        nm.project_rows(&mut p, &mut ProjScratch::new());
        nm.check(&p).unwrap();
        for i in 0..3 {
            let tail_nnz = p.row(i)[8..10].iter().filter(|&&v| v != 0.0).count();
            assert!(tail_nnz <= 1);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_n_above_m() {
        NmStructured::new(5, 4);
    }
}
