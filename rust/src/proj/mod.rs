//! Projection operators — the first-class constraint-set subsystem.
//!
//! The paper's central insight is that activation-aware pruning and
//! quantization are *one* algorithm, projected gradient descent, differing
//! only in the projection operator applied after each gradient step:
//!
//! ```text
//! Θ ← Proj_C(Θ + η(W−Θ)C)
//! ```
//!
//! This module makes `Proj_C` a value. Every constraint set the crate knows
//! implements [`Projection`]; the AWP driver, the backends and the pipeline
//! verifier all route through it, so adding a constraint set means adding
//! one type here instead of touching driver/backend/verifier/CLI:
//!
//! | operator            | constraint set                    | paper     |
//! |---------------------|-----------------------------------|-----------|
//! | [`RowTopK`]         | `C_row`: ≤ k nonzeros per row     | eq. (5)   |
//! | [`NmStructured`]    | ≤ n nonzeros per aligned m-group  | §5 (2:4)  |
//! | [`GroupedIntGrid`]  | `C_INTb`: grouped affine INT grid | §4.2      |
//! | [`Intersect`]       | sparsity ∩ grid (mask survives)   | §4.3      |
//!
//! Projections mutate their input **in place** and take a [`ProjScratch`]
//! for any per-row working memory, so the PGD inner loop — driven through
//! [`PgdWorkspace`] — performs zero `Matrix` allocations after warm-up
//! (see `PROJECTIONS.md` for the catalog, laws and extension guide).
//!
//! Semantics are pinned: [`RowTopK`] is bit-identical to
//! [`crate::tensor::topk::hard_threshold_rows`], [`NmStructured::new`]`(2,4)`
//! to [`crate::sparse::project_2_4`], [`GroupedIntGrid`] to
//! [`crate::quant::project_qmax`], and [`Intersect`] to the §4.3 joint
//! composition (`rust/tests/proj_laws.rs` enforces all four).

pub mod grid;
pub mod intersect;
pub mod nm;
pub mod row_topk;
pub mod workspace;

pub use grid::GroupedIntGrid;
pub use intersect::Intersect;
pub use nm::NmStructured;
pub use row_topk::RowTopK;
pub use workspace::PgdWorkspace;

use anyhow::Result;

use crate::tensor::Matrix;

/// A projection onto a constraint set `C`: `z ← argmin_{θ ∈ C} ‖θ − z‖_F`,
/// applied row-wise and in place.
///
/// Implementations must be:
/// * **idempotent** — `proj(proj(z)) == proj(z)`;
/// * **allocation-free** after scratch warm-up — per-row working memory
///   comes from the caller's [`ProjScratch`], never from fresh `Vec`s;
/// * **deterministic** — ties broken by column order, so outputs are
///   reproducible across runs and worker counts.
///
/// `rust/tests/proj_laws.rs` sweeps these laws for every operator.
pub trait Projection: Send + Sync {
    /// Short stable identifier (e.g. `"row-topk"`).
    fn name(&self) -> &'static str;

    /// Human-readable parameterisation (e.g. `"nm(2:4)"`), used in error
    /// messages and backend-lowering diagnostics.
    fn describe(&self) -> String;

    /// Project `z` onto the constraint set, in place.
    fn project_rows(&self, z: &mut Matrix, scratch: &mut ProjScratch);

    /// Verify that `theta` lies in the constraint set (the pipeline's
    /// `verify` pass and the tests' oracle).
    fn check(&self, theta: &Matrix) -> Result<()>;

    /// Structured view for backends that lower projections to AOT programs
    /// (`runtime::HloBackend`). The default is [`ProjKind::Opaque`]: the
    /// operator runs on the CPU backend only.
    fn kind(&self) -> ProjKind<'_> {
        ProjKind::Opaque
    }
}

/// Structured description of a projection, consumed by the HLO backend to
/// pick the matching AOT chunk program (`prune`/`quant`/`joint`). New
/// operators without an AOT artifact stay [`ProjKind::Opaque`] and are
/// CPU-only until lowered.
#[derive(Clone, Copy)]
pub enum ProjKind<'a> {
    /// per-row top-k hard thresholding (`H_k`)
    RowTopK { k: usize },
    /// N:M semi-structured sparsity
    Nm { n: usize, m: usize },
    /// grouped affine INT grid (`Proj_INT`)
    IntGrid { qmax: f32, group: usize },
    /// sparsity ∩ grid with mask re-application
    Intersect {
        sparse: &'a dyn Projection,
        grid: &'a dyn Projection,
    },
    /// no structured lowering — CPU backend only
    Opaque,
}

/// Reusable per-call working memory for projections. Buffers grow on first
/// use and are reused afterwards, so a warmed-up scratch makes every
/// projection allocation-free; [`ProjScratch::grow_events`] counts the
/// warm-up growths (the workspace's allocation audit reads it).
#[derive(Default)]
pub struct ProjScratch {
    /// row-length f32 buffer (RowTopK's |.| quickselect)
    pub(crate) vals: Vec<f32>,
    /// group-length index buffer (NmStructured's per-group argsort)
    pub(crate) idx: Vec<usize>,
    /// matrix-sized zero-pattern snapshot (Intersect's mask re-application)
    pub(crate) mask: Vec<bool>,
    grows: usize,
}

impl ProjScratch {
    pub fn new() -> Self {
        ProjScratch::default()
    }

    /// f32 buffer of length `n` (grown once, reused afterwards).
    pub(crate) fn vals(&mut self, n: usize) -> &mut [f32] {
        if self.vals.len() < n {
            self.vals.resize(n, 0.0);
            self.grows += 1;
        }
        &mut self.vals[..n]
    }

    /// index buffer of length `n`.
    pub(crate) fn idx(&mut self, n: usize) -> &mut [usize] {
        if self.idx.len() < n {
            self.idx.resize(n, 0);
            self.grows += 1;
        }
        &mut self.idx[..n]
    }

    /// Ensure the zero-pattern mask holds `n` entries; callers index
    /// `self.mask[..n]` directly afterwards.
    pub(crate) fn ensure_mask(&mut self, n: usize) {
        if self.mask.len() < n {
            self.mask.resize(n, false);
            self.grows += 1;
        }
    }

    /// How many times any buffer grew — stable after warm-up.
    pub fn grow_events(&self) -> usize {
        self.grows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_grows_once_per_buffer() {
        let mut s = ProjScratch::new();
        assert_eq!(s.grow_events(), 0);
        s.vals(16);
        s.vals(16);
        s.vals(8); // smaller: no growth
        assert_eq!(s.grow_events(), 1);
        s.idx(4);
        s.ensure_mask(64);
        let g = s.grow_events();
        s.idx(4);
        s.ensure_mask(64);
        assert_eq!(s.grow_events(), g);
        s.vals(32); // larger: grows again
        assert_eq!(s.grow_events(), g + 1);
    }

    #[test]
    fn describe_strings_are_informative() {
        assert_eq!(RowTopK::new(8).describe(), "row-topk(k=8)");
        assert_eq!(NmStructured::new(2, 4).describe(), "nm(2:4)");
        assert_eq!(GroupedIntGrid::new(15.0, 32).describe(),
                   "int-grid(qmax=15, group=32)");
        let i = Intersect::new(NmStructured::new(4, 8),
                               GroupedIntGrid::new(15.0, 32));
        assert_eq!(i.describe(), "nm(4:8) ∩ int-grid(qmax=15, group=32)");
    }
}
