//! The §4.3 intersection — a sparsity set ∩ the INT grid, with the
//! sparsity mask re-applied after the grid projection so zeros survive
//! (exact zero is always representable; grid values near zero are not
//! necessarily *at* zero, hence the explicit re-mask).
//!
//! Replaces the inline zip loop that used to live in
//! `compress::awp_cpu::joint_chunk`; bit-identity with that composition is
//! pinned in `rust/tests/proj_laws.rs`.

use anyhow::Result;

use super::{GroupedIntGrid, ProjKind, ProjScratch, Projection};
use crate::tensor::Matrix;

/// `Proj_INT ∘ Proj_sparse` with mask survival: project onto the sparsity
/// set, snapshot the zero pattern, project onto the grid, then re-zero the
/// masked entries. Generic over the sparsity half so both `C_row` (joint
/// unstructured) and N:M (joint semi-structured) compose with the grid.
pub struct Intersect<S: Projection> {
    sparse: S,
    grid: GroupedIntGrid,
}

impl<S: Projection> Intersect<S> {
    pub fn new(sparse: S, grid: GroupedIntGrid) -> Self {
        Intersect { sparse, grid }
    }

    pub fn sparse(&self) -> &S {
        &self.sparse
    }

    pub fn grid(&self) -> &GroupedIntGrid {
        &self.grid
    }
}

impl<S: Projection> Projection for Intersect<S> {
    fn name(&self) -> &'static str {
        "intersect"
    }

    fn describe(&self) -> String {
        format!("{} ∩ {}", self.sparse.describe(), self.grid.describe())
    }

    fn project_rows(&self, z: &mut Matrix, scratch: &mut ProjScratch) {
        self.sparse.project_rows(z, scratch);
        // snapshot the zero pattern: these entries must survive the grid
        let len = z.data.len();
        scratch.ensure_mask(len);
        for (m, v) in scratch.mask[..len].iter_mut().zip(&z.data) {
            *m = *v == 0.0;
        }
        self.grid.project_rows(z, scratch);
        for (v, m) in z.data.iter_mut().zip(&scratch.mask[..len]) {
            if *m {
                *v = 0.0;
            }
        }
    }

    fn check(&self, theta: &Matrix) -> Result<()> {
        self.sparse.check(theta)?;
        self.grid.check(theta)
    }

    fn kind(&self) -> ProjKind<'_> {
        ProjKind::Intersect { sparse: &self.sparse, grid: &self.grid }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proj::{NmStructured, RowTopK};
    use crate::quant;
    use crate::tensor::topk;

    #[test]
    fn matches_inline_joint_composition() {
        // the exact composition joint_chunk used to inline
        for seed in 0..6u64 {
            let z = Matrix::randn(8, 64, seed);
            let zp = topk::hard_threshold_rows(&z, 16);
            let mut want = quant::project_qmax(&zp, 15.0, 32);
            for (q, p) in want.data.iter_mut().zip(&zp.data) {
                if *p == 0.0 {
                    *q = 0.0;
                }
            }
            let mut got = z.clone();
            Intersect::new(RowTopK::new(16), GroupedIntGrid::new(15.0, 32))
                .project_rows(&mut got, &mut ProjScratch::new());
            assert_eq!(got.data, want.data, "seed={seed}");
        }
    }

    #[test]
    fn zeros_survive_the_grid() {
        let z = Matrix::randn(6, 32, 9);
        let mut p = z.clone();
        let proj = Intersect::new(NmStructured::new(2, 4),
                                  GroupedIntGrid::new(3.0, 16));
        proj.project_rows(&mut p, &mut ProjScratch::new());
        proj.check(&p).unwrap();
        // at least the N:M sparsity (the coarse INT2 grid may round small
        // survivors to its zero level, never the other way)
        assert!(p.sparsity() >= 0.5 - 1e-9, "sparsity {}", p.sparsity());
        // every entry the N:M half zeroed is still exactly zero
        let mut nm_only = z.clone();
        NmStructured::new(2, 4).project_rows(&mut nm_only, &mut ProjScratch::new());
        for (s, j) in nm_only.data.iter().zip(&p.data) {
            if *s == 0.0 {
                assert_eq!(*j, 0.0);
            }
        }
    }
}
