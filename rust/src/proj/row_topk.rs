//! `H_k` — per-row top-k hard thresholding (the `C_row` constraint set of
//! eq. 5), in place and bit-identical to
//! [`crate::tensor::topk::hard_threshold_rows`].

use anyhow::{bail, Result};

use super::{ProjKind, ProjScratch, Projection};
use crate::tensor::Matrix;

/// Keep the `k` largest-|.| entries of every row, zero the rest. Ties at
/// the threshold are broken by column order (exact-k on every row with
/// `k ≤ cols` nonzero candidates), matching `topk::row_topk_mask`.
#[derive(Clone, Copy, Debug)]
pub struct RowTopK {
    k: usize,
}

impl RowTopK {
    pub fn new(k: usize) -> Self {
        RowTopK { k }
    }

    pub fn k(&self) -> usize {
        self.k
    }
}

impl Projection for RowTopK {
    fn name(&self) -> &'static str {
        "row-topk"
    }

    fn describe(&self) -> String {
        format!("row-topk(k={})", self.k)
    }

    fn project_rows(&self, z: &mut Matrix, scratch: &mut ProjScratch) {
        let (m, n) = z.shape();
        let k = self.k.min(n);
        if k == 0 {
            z.data.fill(0.0);
            return;
        }
        if k == n {
            return;
        }
        for i in 0..m {
            let row = &mut z.data[i * n..(i + 1) * n];
            // threshold = k-th largest |entry| (quickselect on scratch)
            let mags = scratch.vals(n);
            for (s, v) in mags.iter_mut().zip(row.iter()) {
                *s = v.abs();
            }
            mags.select_nth_unstable_by(k - 1, |a, b| b.partial_cmp(a).unwrap());
            let thr = mags[k - 1];
            // mirror topk::row_topk_mask exactly: keep everything strictly
            // above, then fill the remaining slots with at-threshold
            // entries in column order
            let above = row.iter().filter(|v| v.abs() > thr).count();
            let mut fill = k - above;
            for v in row.iter_mut() {
                let a = v.abs();
                if a > thr {
                    continue;
                }
                if a == thr && fill > 0 {
                    fill -= 1;
                    continue;
                }
                *v = 0.0;
            }
        }
    }

    fn check(&self, theta: &Matrix) -> Result<()> {
        let k = self.k.min(theta.cols);
        for i in 0..theta.rows {
            let nnz = theta.row(i).iter().filter(|&&v| v != 0.0).count();
            if nnz > k {
                bail!("row {i} has {nnz} > k={k} nonzeros");
            }
        }
        Ok(())
    }

    fn kind(&self) -> ProjKind<'_> {
        ProjKind::RowTopK { k: self.k }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::topk;

    #[test]
    fn matches_hard_threshold_rows() {
        for seed in 0..8u64 {
            let z = Matrix::randn(9, 33, seed);
            for k in [0usize, 1, 7, 32, 33, 40] {
                let want = topk::hard_threshold_rows(&z, k);
                let mut got = z.clone();
                RowTopK::new(k).project_rows(&mut got, &mut ProjScratch::new());
                assert_eq!(got.data, want.data, "seed={seed} k={k}");
            }
        }
    }

    #[test]
    fn exact_k_under_ties() {
        let mut z = Matrix::from_vec(1, 5, vec![1.0, -1.0, 1.0, 0.5, 1.0]);
        RowTopK::new(2).project_rows(&mut z, &mut ProjScratch::new());
        // ties broken by column order: first two 1.0s survive
        assert_eq!(z.data, vec![1.0, -1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn check_flags_violations() {
        let dense = Matrix::randn(4, 16, 0);
        assert!(RowTopK::new(8).check(&dense).is_err());
        let mut ok = dense.clone();
        RowTopK::new(8).project_rows(&mut ok, &mut ProjScratch::new());
        RowTopK::new(8).check(&ok).unwrap();
    }
}
