//! `Proj_INT` — the grouped affine INT grid (`C_INTb`), in place and
//! bit-identical to [`crate::quant::project_qmax`] (the CPU mirror of the
//! L1 Pallas kernel `python/compile/kernels/quant_project.py`).

use anyhow::{bail, Result};

use super::{ProjKind, ProjScratch, Projection};
use crate::tensor::Matrix;

/// Per-group min/max-fitted affine grid with `qmax + 1` levels and an
/// integer zero-point (zero is exactly representable whenever a group
/// straddles 0 — what lets pruned weights survive the grid in §4.3).
///
/// `group` is clamped to the matrix width at application time (matching
/// the historical `group.min(d_in)` of the CPU backend), so micro-shapes
/// narrower than the configured group still project; the configured value
/// is what backend lowering validates against the AOT artifacts.
#[derive(Clone, Copy, Debug)]
pub struct GroupedIntGrid {
    qmax: f32,
    group: usize,
}

impl GroupedIntGrid {
    pub fn new(qmax: f32, group: usize) -> Self {
        assert!(qmax >= 1.0, "qmax must be >= 1, got {qmax}");
        assert!(group >= 1, "group must be >= 1");
        GroupedIntGrid { qmax, group }
    }

    pub fn qmax(&self) -> f32 {
        self.qmax
    }

    pub fn group(&self) -> usize {
        self.group
    }
}

impl Projection for GroupedIntGrid {
    fn name(&self) -> &'static str {
        "int-grid"
    }

    fn describe(&self) -> String {
        format!("int-grid(qmax={}, group={})", self.qmax, self.group)
    }

    fn project_rows(&self, z: &mut Matrix, _scratch: &mut ProjScratch) {
        let group = self.group.min(z.cols);
        assert_eq!(z.cols % group, 0,
                   "d_in={} not a multiple of group={group}", z.cols);
        let qmax = self.qmax;
        for i in 0..z.rows {
            let row = z.row_mut(i);
            for g in (0..row.len()).step_by(group) {
                let s = &mut row[g..g + group];
                let lo = s.iter().cloned().fold(f32::MAX, f32::min);
                let hi = s.iter().cloned().fold(f32::MIN, f32::max);
                let scale = (hi - lo) / qmax;
                if scale > 0.0 {
                    let zp = (-lo / scale).round_ties_even();
                    for v in s.iter_mut() {
                        let q = ((*v / scale).round_ties_even() + zp)
                            .clamp(0.0, qmax);
                        *v = (q - zp) * scale;
                    }
                } else {
                    // flat group: single grid point
                    for v in s.iter_mut() {
                        *v = lo;
                    }
                }
            }
        }
    }

    fn check(&self, theta: &Matrix) -> Result<()> {
        // Re-projection must be (nearly) a no-op. Zeros are skipped: under
        // an intersection with a sparsity set they are off the min/max-
        // refitted grid, but exact zero is always representable (integer
        // zero-point), so only non-zero entries are meaningful here.
        let mut re = theta.clone();
        self.project_rows(&mut re, &mut ProjScratch::new());
        for (i, (a, b)) in theta.data.iter().zip(&re.data).enumerate() {
            if *a != 0.0 && (a - b).abs() > 1e-4 * a.abs().max(1e-3) {
                bail!("entry {i} off-grid: {a} vs reprojected {b}");
            }
        }
        Ok(())
    }

    fn kind(&self) -> ProjKind<'_> {
        ProjKind::IntGrid { qmax: self.qmax, group: self.group }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;

    #[test]
    fn matches_project_qmax() {
        for seed in 0..6u64 {
            let z = Matrix::randn(7, 64, seed);
            for bits in [2u32, 3, 4] {
                let qmax = (1u32 << bits) as f32 - 1.0;
                let want = quant::project_qmax(&z, qmax, 32);
                let mut got = z.clone();
                GroupedIntGrid::new(qmax, 32)
                    .project_rows(&mut got, &mut ProjScratch::new());
                assert_eq!(got.data, want.data, "seed={seed} bits={bits}");
            }
        }
    }

    #[test]
    fn group_clamps_to_width() {
        // 16-wide matrix with group 32: one group per row (historical
        // group.min(d_in) behaviour)
        let z = Matrix::randn(3, 16, 1);
        let want = quant::project_qmax(&z, 15.0, 16);
        let mut got = z.clone();
        GroupedIntGrid::new(15.0, 32).project_rows(&mut got, &mut ProjScratch::new());
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn check_accepts_own_output_rejects_raw() {
        let z = Matrix::randn(4, 32, 2);
        let grid = GroupedIntGrid::new(7.0, 16);
        assert!(grid.check(&z).is_err());
        let mut q = z.clone();
        grid.project_rows(&mut q, &mut ProjScratch::new());
        grid.check(&q).unwrap();
    }

    #[test]
    fn flat_group_survives() {
        let mut z = Matrix::from_fn(2, 16, |_, _| 0.7);
        GroupedIntGrid::new(15.0, 16).project_rows(&mut z, &mut ProjScratch::new());
        for v in &z.data {
            assert_eq!(*v, 0.7);
        }
    }
}
