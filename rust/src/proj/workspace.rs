//! The allocation-free PGD core: two preallocated ping-pong buffers plus
//! projection scratch, so a 200-iteration prune performs zero `Matrix`
//! allocations after warm-up (the old path allocated a fresh gradient
//! matrix, a top-k mask and a projected copy *per iteration* — ~600 large
//! allocations per layer).

use crate::tensor::{ops, Matrix};

use super::{ProjScratch, Projection};

/// Preallocated state for a PGD run on one layer: the current iterate, a
/// same-shaped step buffer they ping-pong through, and the projections'
/// scratch. Create once per `(W, C)` site, then [`PgdWorkspace::step`] is
/// allocation-free ([`PgdWorkspace::alloc_events`] audits this).
pub struct PgdWorkspace {
    cur: Matrix,
    next: Matrix,
    scratch: ProjScratch,
    matrix_allocs: usize,
}

impl PgdWorkspace {
    /// Start a workspace from `init` (moved in). The spare step buffer is
    /// allocated lazily on the first [`PgdWorkspace::step`] — backends
    /// that never step locally (the HLO path only reads the iterate and
    /// installs program outputs) pay nothing for it.
    pub fn new(init: Matrix) -> Self {
        let next = Matrix::zeros(0, 0);
        PgdWorkspace { cur: init, next, scratch: ProjScratch::new(), matrix_allocs: 0 }
    }

    /// The current iterate.
    pub fn theta(&self) -> &Matrix {
        &self.cur
    }

    /// Replace the current iterate with an externally produced one (the
    /// HLO backend's program output, the joint schedule's annealed Wanda
    /// solutions). Shape must match.
    pub fn install(&mut self, theta: Matrix) {
        assert_eq!(theta.shape(), self.cur.shape(), "workspace shape mismatch");
        self.cur = theta;
    }

    /// One `Θ ← Proj(Θ + η(W−Θ)C)` iteration, in place: the fused gradient
    /// step writes into the spare buffer, the projection mutates it there,
    /// and the buffers swap. No allocations after warm-up.
    pub fn step(&mut self, w: &Matrix, c: &Matrix, eta: f32, proj: &dyn Projection) {
        if self.next.shape() != self.cur.shape() {
            self.next = Matrix::zeros(self.cur.rows, self.cur.cols);
            self.matrix_allocs += 1;
        }
        ops::pgd_step_into(w, &self.cur, c, eta, &mut self.next);
        proj.project_rows(&mut self.next, &mut self.scratch);
        std::mem::swap(&mut self.cur, &mut self.next);
    }

    /// Finish the run, handing the final iterate back without a copy.
    pub fn into_theta(self) -> Matrix {
        self.cur
    }

    /// Allocation audit: buffer allocations performed by the workspace
    /// (its own warm-up plus projection-scratch growth). Stable across
    /// further [`PgdWorkspace::step`] calls once warmed up — the tier-1
    /// tests assert exactly that.
    pub fn alloc_events(&self) -> usize {
        self.matrix_allocs + self.scratch.grow_events()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proj::{GroupedIntGrid, Intersect, RowTopK};
    use crate::tensor::topk;

    #[test]
    fn step_matches_compose_of_free_functions() {
        let w = Matrix::randn(12, 32, 0);
        let c = Matrix::randn_gram(32, 1);
        let th0 = topk::hard_threshold_rows(&w, 8);
        let mut ws = PgdWorkspace::new(th0.clone());
        let proj = RowTopK::new(8);
        let mut reference = th0;
        for _ in 0..5 {
            ws.step(&w, &c, 0.05, &proj);
            let z = crate::tensor::ops::pgd_step(&w, &reference, &c, 0.05);
            reference = topk::hard_threshold_rows(&z, 8);
            assert_eq!(ws.theta().data, reference.data);
        }
    }

    #[test]
    fn steps_are_allocation_free_after_warmup() {
        let w = Matrix::randn(16, 64, 2);
        let c = Matrix::randn_gram(64, 3);
        let mut ws = PgdWorkspace::new(w.clone());
        let joint = Intersect::new(RowTopK::new(16), GroupedIntGrid::new(15.0, 32));
        ws.step(&w, &c, 0.01, &joint); // warm-up: scratch buffers grow here
        let warmed = ws.alloc_events();
        for _ in 0..50 {
            ws.step(&w, &c, 0.01, &joint);
            ws.step(&w, &c, 0.01, &RowTopK::new(16));
        }
        assert_eq!(ws.alloc_events(), warmed,
                   "PGD inner loop allocated after warm-up");
    }

    #[test]
    fn install_swaps_the_iterate() {
        let a = Matrix::randn(4, 8, 4);
        let b = Matrix::randn(4, 8, 5);
        let mut ws = PgdWorkspace::new(a);
        ws.install(b.clone());
        assert_eq!(ws.into_theta().data, b.data);
    }
}
