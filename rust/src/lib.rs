//! # awp — full-system reproduction of *AWP: Activation-Aware Weight Pruning
//! # and Quantization with Projected Gradient Descent* (Liu et al., 2025)
//!
//! This crate is the Layer-3 coordinator of a three-layer Rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernels for the PGD hot path
//!   (`Z = Θ + η(W−Θ)C`) and the INT-grid projection;
//! * **L2** (`python/compile/`) — the transformer LM, AdamW train step,
//!   calibration Gram capture and chunked AWP programs, AOT-lowered to HLO
//!   text by `make artifacts`;
//! * **L3** (this crate) — everything at run time: PJRT runtime, training
//!   loop, calibration orchestration, the layer-wise compression pipeline
//!   with AWP and every baseline the paper compares against (Magnitude,
//!   Wanda, SparseGPT, RTN, AWQ, GPTQ), perplexity evaluation, and the
//!   experiment harness that regenerates each of the paper's tables/figures.
//!
//! Python never runs on the request path; after `make artifacts` the `repro`
//! binary is self-contained.
//!
//! ## Parallelism and the thread budget
//!
//! Every `(W, C)` site is an independent PGD problem, so the coordinator
//! runs layer jobs (and whole experiment-table cells) on a worker pool —
//! [`coordinator::executor::Executor`]. Two knobs control it:
//!
//! * **`AWP_THREADS`** (env) — the machine thread budget. Everything
//!   parallel in the crate (the executor's workers *and* the GEMM
//!   row-panel threads in [`tensor::ops`]) derives from it; unset, it
//!   defaults to the available parallelism.
//! * **`--jobs N`** (CLI) — how many of those threads become *outer*
//!   layer-job/table-cell workers.
//!
//! The budget rule: **outer workers × inner GEMM threads ≤ `AWP_THREADS`**.
//! The executor grants each worker `AWP_THREADS / jobs` inner threads
//! (min 1), so the inner GEMM parallelism shrinks as the outer worker
//! count grows instead of oversubscribing cores. `--jobs 1` (or
//! `AWP_THREADS=1`) reproduces the sequential path bit-for-bit; outputs
//! are deterministic at *any* worker count (results are reassembled in
//! plan order — see `EXECUTOR_DESIGN.md`).
//!
//! ## Calibration cache
//!
//! The calibration protocol is deterministic, so each model's activation
//! Grams `C = X Xᵀ / n` are a pure function of `(checkpoint, calibration
//! config)`. [`coordinator::cache`] exploits that with a two-layer
//! calibration-artifact cache:
//!
//! * an **`Arc`-shared memory layer** (per-key once-cells) — concurrent
//!   sweep jobs asking for the same model's Grams compute them once and
//!   share the allocation, without serializing on the PJRT actor;
//! * a **disk layer** (`--cache-dir`, default `cache/grams`; `--no-cache`
//!   disables it) — `AWPGRAM1` files keyed by a content hash of (model
//!   id, checkpoint fingerprint, calibration corpus/seed/batch config).
//!   A warm run loads Grams without submitting a single `calib_capture`
//!   execution; corrupt or stale files are discarded and recomputed, and
//!   compressed output is bit-identical cold vs. warm
//!   (`rust/tests/gram_cache.rs`).
//!
//! `experiment all` schedules **cross-model**: per-model preparation
//! (train/load, calibrate-or-load, dense baseline) runs as executor jobs,
//! then every table's cells interleave on the same pool, cost-weighted by
//! `Job::cost` for the live progress/ETA line ([`coordinator::sweep`]).
//!
//! ## Projection operators
//!
//! Pruning and quantization are one algorithm — PGD — differing only in
//! the projection applied after each gradient step. [`proj`] makes that
//! literal: every constraint set ([`proj::RowTopK`], [`proj::NmStructured`]
//! for arbitrary N:M incl. 2:4, [`proj::GroupedIntGrid`], and their
//! [`proj::Intersect`]) implements the [`proj::Projection`] trait, and the
//! AWP backends expose a single `step_chunk` driven through a
//! [`proj::PgdWorkspace`] — two preallocated ping-pong buffers, so the PGD
//! inner loop performs **zero `Matrix` allocations** after warm-up.
//! `CompressionSpec::projection` resolves a spec to its operator; the
//! pipeline verifier (`compress::traits::check_constraints`) and the HLO
//! backend's AOT-program lowering consume the same resolution. See
//! `PROJECTIONS.md` for the catalog, the projection laws the tests sweep,
//! and how to add an operator.
//!
//! ## Compressed artifacts
//!
//! Compressing a site yields a dense f32 Θ whose entries live in a tiny
//! set — b-bit grid points for quantized sites, sparse survivors for
//! pruned ones. [`artifact`] stores each site in that natural
//! representation: an `AWPPACK1` container ([`artifact::ModelArtifact`])
//! holding grouped b-bit codes + per-group scale/zero-point
//! (mirroring [`proj::GroupedIntGrid`]), per-group value palettes, packed
//! N:M/row-sparse survivor masks, or dense f32 fallback — every variant
//! decode-verified **bit-identical** to the in-memory Θ at encode time.
//! Artifacts are keyed by (Gram cache key, compression spec, method)
//! ([`artifact::ArtifactKey`]) with the same rename-atomic write /
//! identity-revalidation / corrupt-file-recompute discipline as the Gram
//! cache, and they persist each site's layer report too — so a **warm
//! sweep rerun submits zero compression jobs**
//! ([`coordinator::pipeline::compress_model_cached`], `--artifact-dir`,
//! default `cache/artifacts`). A packed execution path
//! ([`artifact::PackedLinear::matmul`] streaming dequant GEMM,
//! [`artifact::PackedLinear::matmul_sparse`] survivor-only N:M GEMM)
//! consumes the packed weights directly, and `repro eval --from-artifact`
//! reproduces the dense path's quality numbers from the packed file alone
//! (`repro inspect` prints the per-site footprint). See ARTIFACTS.md.
//!
//! ## Native inference
//!
//! [`infer`] is the native CPU transformer forward pass (embedding,
//! pre-norm RoPE attention + SiLU MLP blocks, tied head — mirroring
//! `python/compile/model.py::forward`) in which every linear site
//! dispatches through [`infer::LinearOp`]: `Dense(&Matrix)` runs the
//! blocked row-panel GEMM, `Packed(&PackedLinear)` runs the streaming
//! dequant / survivor-only kernels straight off the packed bytes. A
//! compressed artifact therefore *executes* without ever being assembled
//! back into a dense f32 checkpoint, and because every GEMM variant
//! shares the dense kernel's accumulation order, the packed and dense
//! forward passes are **bit-identical** — logits, NLL, perplexity and
//! greedy generation (`rust/tests/native_forward.rs`, plus
//! `prop_native_packed_forward_matches_dense`). CLI: `repro eval
//! --native` (runtime-free perplexity), `repro eval --native
//! --from-artifact <file.apack>` (packed serving, zero decode-to-dense
//! assemblies), `repro generate --native`. All forward-pass parallelism
//! (GEMM panels, attention `(batch, head)` blocks, per-position NLL) runs
//! under the `AWP_THREADS` budget and is thread-count invariant.
//!
//! ## Fast kernels
//!
//! The bit-identical packed kernels above are the **reference tier** of a
//! two-tier dispatch ([`tensor::KernelTier`]). The **fast tier** computes
//! in the compressed domain instead of decoding first: integer-accumulate
//! GEMM over the b-bit codes with one per-(row, group) scale/zero-point
//! rescale (`Σ (q−zp)·s·b = s·(Σ q·b − zp·Σ b)`, with the group column
//! sums hoisted out of the row loop), cache-blocked survivor-only GEMM
//! for masks, palette-LUT GEMM, and runtime-selected AVX2+FMA row panels
//! with a portable scalar fallback ([`tensor::simd`]). Selection:
//! [`infer::NativeModel::set_tier`], CLI `--fast` on `eval --native` /
//! `generate --native`, or `AWP_KERNEL_TIER=fast`. The fast tier changes
//! accumulation order, so it is validated by tolerance-based differential
//! tests against the reference tier (`rust/tests/fast_kernels.rs`) — and
//! stays thread-count invariant. Perf is tracked by `repro bench-json`
//! (`BENCH_7.json`) and gated by `cargo bench --bench kernels --
//! --baseline <name>`. Policy, tolerance bounds and how to add a kernel:
//! KERNELS.md.
//!
//! ## Serving
//!
//! [`infer::DecodeSession`] gives the native engine KV-cached incremental
//! decode: per-block K/V rows plus the RoPE position offset, so
//! generation pays one batched [`infer::NativeModel::prefill`] for the
//! prompt and an O(ctx) [`infer::NativeModel::decode_step`] per token —
//! bit-identical to the full-window forward at the reference tier
//! (`rust/tests/serve_decode.rs`). [`serve`] puts a long-lived server in
//! front of it: `repro serve --from-artifact <file.apack>` loads a packed
//! artifact once and serves `/v1/generate` (per-session KV continuation),
//! `/v1/perplexity`, `/v1/inspect` and `/healthz` over a dependency-free
//! HTTP/1.1 layer, with an [`serve::SessionStore`] LRU cap on live
//! sessions, a worker pool under the `AWP_THREADS` budget, structured
//! per-request log lines and graceful SIGINT drain. Serving defaults to
//! the fast kernel tier. Endpoint schemas and operations: SERVING.md.
//!
//! ## Observability
//!
//! [`obs`] is the cross-cutting metrics + tracing layer every subsystem
//! emits into. [`obs::metrics`] keeps a process-global registry of atomic
//! counters, gauges and fixed-bucket histograms (one relaxed atomic add
//! per hot-path observation; globally disableable to a single relaxed
//! load) covering the request path (`awp_requests_total` by route ×
//! status, decode-tick latency, batch occupancy, queue wait), session
//! residency (KV bytes, evictions), the Gram/artifact caches, executor
//! job durations, and kernel-tier busy time — served as Prometheus text
//! on `GET /metrics` and JSON on `GET /v1/stats`. [`obs::trace`] assigns
//! every request a trace id (in every log line) and, under `repro
//! serve|compress --trace-out <file>`, records RAII spans across the
//! serve → batcher → infer path into a bounded sink exported as Chrome
//! trace-event JSON. `repro serve --log-json` switches the per-request
//! log to one JSONL object per request. Instrumentation never changes
//! arithmetic — the bit-identity contracts hold with it on or off, and
//! its residual cost is tracked by `bench-json`'s `obs_overhead` section.
//! Inventory, span hierarchy and overhead policy: OBSERVABILITY.md.
//!
//! ## Quick tour
//!
//! ```no_run
//! use awp::compress::{awp_cpu::AwpCpu, traits::{LayerCompressor, CompressionSpec}};
//! use awp::tensor::Matrix;
//!
//! // Compress one layer: W (d_out x d_in) against activation Gram C.
//! let w = Matrix::randn(64, 64, 0);
//! let c = Matrix::randn_gram(64, 1);
//! let spec = CompressionSpec::prune(0.5);
//! let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
//! println!("activation-aware loss: {}", out.stats.final_loss);
//! ```
//!
//! ## Documentation
//!
//! The repo-level docs map one-to-one onto the subsystems (same index as
//! README.md):
//!
//! * **README.md** — paper summary, subsystem map, full CLI surface;
//! * **EXECUTOR_DESIGN.md** — worker pool, thread budget, determinism
//!   ([`coordinator::executor`]);
//! * **PROJECTIONS.md** — projection-operator catalog and laws ([`proj`]);
//! * **ARTIFACTS.md** — `AWPPACK1` container, key schema, packed
//!   execution ([`artifact`]);
//! * **KERNELS.md** — the two-tier GEMM dispatch, tolerance policy, perf
//!   trajectory ([`tensor::simd`], [`tensor::ops`]);
//! * **SERVING.md** — `repro serve` architecture, endpoint reference,
//!   KV-session lifecycle, operational knobs ([`serve`], [`infer`]);
//! * **OBSERVABILITY.md** — metric inventory, span hierarchy, scrape
//!   quickstart, overhead policy ([`obs`]).

// The CI clippy gate runs `-D warnings`; the seed tree's deliberate styles
// are allowed explicitly rather than rewritten (hand-aligned numeric
// kernels index-loop over matrices, the substrate mirrors external APIs
// with wide argument lists, and `util::json` predates `Display`).
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::inherent_to_string,
    clippy::type_complexity,
    clippy::ptr_arg,
    clippy::len_without_is_empty,
    clippy::should_implement_trait,
    clippy::new_without_default,
    clippy::field_reassign_with_default
)]

pub mod artifact;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod infer;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod proj;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod tensor;
pub mod trainer;
pub mod util;
