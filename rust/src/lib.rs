//! # awp — full-system reproduction of *AWP: Activation-Aware Weight Pruning
//! # and Quantization with Projected Gradient Descent* (Liu et al., 2025)
//!
//! This crate is the Layer-3 coordinator of a three-layer Rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernels for the PGD hot path
//!   (`Z = Θ + η(W−Θ)C`) and the INT-grid projection;
//! * **L2** (`python/compile/`) — the transformer LM, AdamW train step,
//!   calibration Gram capture and chunked AWP programs, AOT-lowered to HLO
//!   text by `make artifacts`;
//! * **L3** (this crate) — everything at run time: PJRT runtime, training
//!   loop, calibration orchestration, the layer-wise compression pipeline
//!   with AWP and every baseline the paper compares against (Magnitude,
//!   Wanda, SparseGPT, RTN, AWQ, GPTQ), perplexity evaluation, and the
//!   experiment harness that regenerates each of the paper's tables/figures.
//!
//! Python never runs on the request path; after `make artifacts` the `repro`
//! binary is self-contained.
//!
//! ## Parallelism and the thread budget
//!
//! Every `(W, C)` site is an independent PGD problem, so the coordinator
//! runs layer jobs (and whole experiment-table cells) on a worker pool —
//! [`coordinator::executor::Executor`]. Two knobs control it:
//!
//! * **`AWP_THREADS`** (env) — the machine thread budget. Everything
//!   parallel in the crate (the executor's workers *and* the GEMM
//!   row-panel threads in [`tensor::ops`]) derives from it; unset, it
//!   defaults to the available parallelism.
//! * **`--jobs N`** (CLI) — how many of those threads become *outer*
//!   layer-job/table-cell workers.
//!
//! The budget rule: **outer workers × inner GEMM threads ≤ `AWP_THREADS`**.
//! The executor grants each worker `AWP_THREADS / jobs` inner threads
//! (min 1), so the inner GEMM parallelism shrinks as the outer worker
//! count grows instead of oversubscribing cores. `--jobs 1` (or
//! `AWP_THREADS=1`) reproduces the sequential path bit-for-bit; outputs
//! are deterministic at *any* worker count (results are reassembled in
//! plan order — see `EXECUTOR_DESIGN.md`).
//!
//! ## Quick tour
//!
//! ```no_run
//! use awp::compress::{awp_cpu::AwpCpu, traits::{LayerCompressor, CompressionSpec}};
//! use awp::tensor::Matrix;
//!
//! // Compress one layer: W (d_out x d_in) against activation Gram C.
//! let w = Matrix::randn(64, 64, 0);
//! let c = Matrix::randn_gram(64, 1);
//! let spec = CompressionSpec::prune(0.5);
//! let out = AwpCpu::default().compress(&w, &c, &spec).unwrap();
//! println!("activation-aware loss: {}", out.stats.final_loss);
//! ```

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod trainer;
pub mod util;
