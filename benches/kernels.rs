//! Kernel-tier GEMM benchmarks — the perf trajectory behind BENCH_6.json,
//! with a criterion-style baseline workflow (the image carries no criterion,
//! so the gating is hand-rolled on `awp::util::bench`):
//!
//! ```bash
//! cargo bench --bench kernels                         # measure + print
//! cargo bench --bench kernels -- --save-baseline main # snapshot to disk
//! cargo bench --bench kernels -- --baseline main      # compare, exit 1 on
//!                                                     # a large regression
//! ```
//!
//! Measures, per compression family (int4/g32, 2:4, 4:8) and serving shape:
//! the dense row-panel GEMM over the decoded weights, the reference packed
//! kernel (streaming dequant / survivor-only), and the fast
//! compressed-domain kernel — plus native forward tokens/sec on all three
//! serving configurations. `--quick` shrinks everything to smoke scale.
//!
//! Baselines live in `target/awp-baselines/<name>.json` (same `awp-bench/1`
//! schema as BENCH_6.json). The regression gate is deliberately loose
//! (-35% on `fast_gflops`, keyed by family × shape): these are wall-clock
//! numbers on shared machines, and the gate exists to catch "the fast tier
//! silently fell back to scalar", not 5% noise. Policy in KERNELS.md.

use std::path::PathBuf;
use std::process::exit;

use awp::report::perf::bench_report;
use awp::util::Json;

/// Fractional `fast_gflops` drop (vs baseline) that fails the gate.
const REGRESSION_TOLERANCE: f64 = 0.35;

fn baseline_path(name: &str) -> PathBuf {
    PathBuf::from("target/awp-baselines").join(format!("{name}.json"))
}

/// `family m x k x n` — the stable identity a row is matched under.
fn row_key(row: &Json) -> String {
    let s = |k: &str| row.expect(k).unwrap().as_str().unwrap().to_string();
    let u = |k: &str| row.expect(k).unwrap().as_usize().unwrap();
    format!("{} {}x{}x{}", s("family"), u("m"), u("k"), u("n"))
}

fn main() {
    let mut quick = false;
    let mut save: Option<String> = None;
    let mut compare: Option<String> = None;
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--quick" => quick = true,
            "--save-baseline" => save = it.next(),
            "--baseline" => compare = it.next(),
            // tolerate harness-style args cargo may forward (e.g. --bench)
            _ => {}
        }
    }

    let report = bench_report(quick).expect("bench suite failed");
    println!();
    for row in report.expect("kernels").unwrap().as_arr().unwrap() {
        let ratio = row.expect("fast_vs_reference").unwrap().as_f64().unwrap();
        println!("{:24} fast/reference = {ratio:.2}x", row_key(row));
    }
    let native = report.expect("native").unwrap();
    println!("native packed fast/reference = {:.2}x",
             native.expect("fast_vs_reference").unwrap().as_f64().unwrap());

    if let Some(name) = save {
        let path = baseline_path(&name);
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, report.to_string() + "\n").unwrap();
        println!("baseline '{name}' saved to {}", path.display());
    }
    if let Some(name) = compare {
        let path = baseline_path(&name);
        let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
            eprintln!("baseline '{name}' unreadable at {}: {e}", path.display());
            exit(2);
        });
        let base = Json::parse(&text).expect("baseline is not valid JSON");
        let base_rows = base.expect("kernels").unwrap().as_arr().unwrap();
        let mut failed = false;
        for row in report.expect("kernels").unwrap().as_arr().unwrap() {
            let key = row_key(row);
            let Some(b) = base_rows.iter().find(|r| row_key(r) == key) else {
                println!("{key:24} (no baseline row — skipped)");
                continue;
            };
            let now = row.expect("fast_gflops").unwrap().as_f64().unwrap();
            let was = b.expect("fast_gflops").unwrap().as_f64().unwrap();
            let floor = was * (1.0 - REGRESSION_TOLERANCE);
            if now < floor {
                println!("{key:24} REGRESSED: {now:.2} GFLOP/s < floor \
                          {floor:.2} (baseline {was:.2})");
                failed = true;
            } else {
                println!("{key:24} ok: {now:.2} GFLOP/s (baseline {was:.2})");
            }
        }
        if failed {
            eprintln!("kernel perf regression vs baseline '{name}'");
            exit(1);
        }
        println!("no regression vs baseline '{name}'");
    }
}
