//! Substrate micro-benches: the L3 hot loops under the compression
//! pipeline (GEMM panels, top-k, quant pack, Gram accumulation, corpus).
//!
//! ```bash
//! cargo bench --bench substrates
//! ```

use awp::data::{Batcher, CorpusConfig, Split, SyntheticCorpus};
use awp::quant::{pack_bits, quantize, QuantSpec};
use awp::tensor::{ops, topk, Matrix};
use awp::util::bench::bench;
use awp::util::Rng;

fn main() {
    println!("== GEMM (thread-parallel blocked) ==");
    for &n in &[128usize, 256, 512, 1024] {
        let a = Matrix::randn(n, n, 0);
        let b = Matrix::randn(n, n, 1);
        let r = bench(&format!("matmul {n}x{n}x{n}"), 0.8, || {
            std::hint::black_box(ops::matmul(&a, &b));
        });
        println!("    ↳ {:.1} GFLOP/s", r.gflops(2.0 * (n as f64).powi(3)));
    }

    println!("\n== fused pgd_step vs unfused (sub+matmul+scale+add) ==");
    for &n in &[256usize, 1024] {
        let w = Matrix::randn(256, n, 2);
        let t = Matrix::randn(256, n, 3);
        let c = Matrix::randn_gram(n, 4);
        bench(&format!("pgd_step fused 256x{n}"), 0.8, || {
            std::hint::black_box(ops::pgd_step(&w, &t, &c, 0.05));
        });
        bench(&format!("pgd_step unfused 256x{n}"), 0.8, || {
            let r = ops::sub(&w, &t);
            let g = ops::matmul(&r, &c);
            std::hint::black_box(ops::add(&t, &ops::scale(&g, 0.05)));
        });
    }

    println!("\n== projections ==");
    let z = Matrix::randn(1024, 1024, 5);
    bench("row_topk mask 1024x1024 k=512", 0.5, || {
        std::hint::black_box(topk::hard_threshold_rows(&z, 512));
    });
    bench("quantize INT4 g32 1024x1024", 0.5, || {
        std::hint::black_box(quantize(&z, QuantSpec::new(4, 32)));
    });
    let q = quantize(&z, QuantSpec::new(4, 32));
    bench("pack INT4 codes 1M", 0.5, || {
        std::hint::black_box(pack_bits(&q.codes, 4));
    });

    println!("\n== loss/grad reductions (stopping criterion path) ==");
    let w = Matrix::randn(1024, 256, 6);
    let t = topk::hard_threshold_rows(&w, 128);
    let c = Matrix::randn_gram(256, 7);
    bench("activation_loss 1024x256", 0.5, || {
        std::hint::black_box(ops::activation_loss(&w, &t, &c));
    });
    bench("grad_frob_norm 1024x256", 0.5, || {
        std::hint::black_box(ops::grad_frob_norm(&w, &t, &c));
    });

    println!("\n== data pipeline ==");
    bench("corpus generate 1MiB", 1.0, || {
        std::hint::black_box(SyntheticCorpus::generate(CorpusConfig {
            total_bytes: 1 << 20,
            ..Default::default()
        }));
    });
    let corpus = SyntheticCorpus::generate(CorpusConfig {
        total_bytes: 1 << 20,
        ..Default::default()
    });
    let batcher = Batcher::new(&corpus, 4, 128);
    let mut rng = Rng::new(0);
    bench("batch sample 4x128", 0.2, || {
        std::hint::black_box(batcher.sample(Split::Train, &mut rng));
    });
}
