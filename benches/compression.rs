//! Per-layer compression cost across methods and shapes — the paper's §3
//! complexity claim: AWP's `O(d_out·d_in²)` GEMM iterations vs the
//! Hessian-inverse (`O(d_in³)` + column sweeps) of SparseGPT/GPTQ, all on
//! the same substrates. One bench per paper table's method set, plus a
//! pipeline-level scaling case (same tiny model, `--jobs` 1/2/4 through
//! the layer-job executor) so BENCH_*.json tracks executor speedup over
//! time.
//!
//! ```bash
//! cargo bench --bench compression
//! ```

use awp::compress::traits::{CompressionSpec, LayerCompressor};
use awp::compress::{
    awq::AwqQuant, gptq::Gptq, magnitude::MagnitudePrune, rtn::RtnQuant,
    sequential::SequentialCombo, sparsegpt::SparseGpt, wanda::WandaPrune, AwpCpu,
};
use awp::tensor::Matrix;
use awp::util::bench::bench;

fn main() {
    // the three weight-shape classes of the `small` model
    let shapes = [(256usize, 256usize), (1024, 256), (256, 1024)];

    println!("== Table 1/2 methods: pruning at 50% ==");
    for &(m, k) in &shapes {
        let w = Matrix::randn(m, k, 1);
        let c = Matrix::randn_gram(k, 2);
        let spec = CompressionSpec::prune(0.5);
        let methods: Vec<(&str, Box<dyn LayerCompressor>)> = vec![
            ("magnitude", Box::new(MagnitudePrune)),
            ("wanda", Box::new(WandaPrune)),
            ("sparsegpt", Box::new(SparseGpt::default())),
            ("awp-cpu", Box::<AwpCpu>::default()),
        ];
        for (name, c_) in methods {
            bench(&format!("prune50 {name} {m}x{k}"), 1.0, || {
                c_.compress(&w, &c, &spec).unwrap();
            });
        }
        println!();
    }

    println!("== Table 3 methods: INT4 quantization (group 32) ==");
    for &(m, k) in &shapes[..2] {
        let w = Matrix::randn(m, k, 3);
        let c = Matrix::randn_gram(k, 4);
        let spec = CompressionSpec::quant(4, 32);
        let methods: Vec<(&str, Box<dyn LayerCompressor>)> = vec![
            ("rtn", Box::new(RtnQuant)),
            ("gptq", Box::new(Gptq::default())),
            ("awq", Box::new(AwqQuant::default())),
            ("awp-cpu", Box::<AwpCpu>::default()),
        ];
        for (name, c_) in methods {
            bench(&format!("quant4 {name} {m}x{k}"), 1.0, || {
                c_.compress(&w, &c, &spec).unwrap();
            });
        }
        println!();
    }

    println!("== Table 4/5 methods: joint 50% + INT4 ==");
    {
        let (m, k) = (256, 256);
        let w = Matrix::randn(m, k, 5);
        let c = Matrix::randn_gram(k, 6);
        let spec = CompressionSpec::joint(0.5, 4, 32);
        let methods: Vec<(&str, Box<dyn LayerCompressor>)> = vec![
            ("awq+wanda", Box::new(SequentialCombo::awq_then_wanda())),
            ("wanda+awq", Box::new(SequentialCombo::wanda_then_awq())),
            ("awp-cpu", Box::<AwpCpu>::default()),
        ];
        for (name, c_) in methods {
            bench(&format!("joint50+int4 {name} {m}x{k}"), 1.5, || {
                c_.compress(&w, &c, &spec).unwrap();
            });
        }
    }

    println!("\n== pipeline scaling: layer-job executor, same model at --jobs 1/2/4 ==");
    {
        use awp::coordinator::calibrate::Grams;
        use awp::coordinator::{compress_model_with, Executor};
        use awp::model::{GramKey, ModelConfig};
        use std::collections::HashMap;

        // multi-layer tiny model: enough independent layer jobs for the
        // pool to overlap (12 sites, LPT-ordered)
        let cfg = ModelConfig {
            name: "bench".into(), vocab: 64, d_model: 128, n_heads: 4,
            n_layers: 2, d_ff: 512, seq_len: 16, batch: 1, decode_len: 8,
            rope_theta: 1e4,
        };
        let ck = awp::trainer::init_checkpoint(&cfg, 7);
        let mut map = HashMap::new();
        for l in 0..cfg.n_layers {
            for key in [GramKey::AttnIn, GramKey::AttnOutIn, GramKey::MlpIn] {
                map.insert((key, l),
                           Matrix::randn_gram(cfg.d_model, 10 * l as u64 + key.index() as u64));
            }
            map.insert((GramKey::MlpDownIn, l), Matrix::randn_gram(cfg.d_ff, 77 + l as u64));
        }
        let grams = Grams { map, tokens: 4096 };
        let spec = CompressionSpec::prune(0.5);
        let compressor = AwpCpu::default();
        for jobs in [1usize, 2, 4] {
            let exec = Executor::with_workers(jobs);
            if exec.workers() != jobs {
                // with_workers clamps to the thread budget — flag it so a
                // plateau in the BENCH series is attributable
                println!("    (jobs={jobs} clamped to {} workers by the \
                          thread budget)", exec.workers());
            }
            bench(&format!("pipeline awp-cpu prune50 jobs={jobs}"), 2.0, || {
                compress_model_with(&ck, &grams, &compressor, &spec, false, &exec)
                    .unwrap();
            });
        }
    }

    println!("\n== PGD inner loop: preallocated workspace vs per-iteration alloc ==");
    {
        // the projection-subsystem tentpole: the workspace ping-pongs two
        // preallocated buffers (zero Matrix allocations per iteration),
        // where the historical path allocated a gradient matrix, a top-k
        // mask and a projected copy every iteration. Same arithmetic —
        // the delta is pure allocator/memory traffic.
        use awp::proj::{GroupedIntGrid, Intersect, PgdWorkspace, RowTopK};
        use awp::tensor::{ops, topk};

        let (m, k) = (256usize, 256usize);
        let w = Matrix::randn(m, k, 11);
        let c = Matrix::randn_gram(k, 12);
        let th0 = topk::hard_threshold_rows(&w, k / 2);
        let eta = (2.0 / c.frob_norm()) as f32;
        let iters = 50;

        let prune = RowTopK::new(k / 2);
        bench(&format!("pgd-loop workspace prune {m}x{k} x{iters}"), 1.0, || {
            let mut ws = PgdWorkspace::new(th0.clone());
            for _ in 0..iters {
                ws.step(&w, &c, eta, &prune);
            }
        });
        bench(&format!("pgd-loop alloc-baseline prune {m}x{k} x{iters}"), 1.0, || {
            let mut th = th0.clone();
            for _ in 0..iters {
                let z = ops::pgd_step(&w, &th, &c, eta);
                th = topk::hard_threshold_rows(&z, k / 2);
            }
        });

        let joint = Intersect::new(RowTopK::new(k / 2), GroupedIntGrid::new(15.0, 32));
        bench(&format!("pgd-loop workspace joint {m}x{k} x{iters}"), 1.0, || {
            let mut ws = PgdWorkspace::new(th0.clone());
            for _ in 0..iters {
                ws.step(&w, &c, eta, &joint);
            }
        });
        bench(&format!("pgd-loop alloc-baseline joint {m}x{k} x{iters}"), 1.0, || {
            let mut th = th0.clone();
            for _ in 0..iters {
                let z = ops::pgd_step(&w, &th, &c, eta);
                let zp = topk::hard_threshold_rows(&z, k / 2);
                let mut zq = awp::quant::project_qmax(&zp, 15.0, 32);
                for (q, p) in zq.data.iter_mut().zip(&zp.data) {
                    if *p == 0.0 {
                        *q = 0.0;
                    }
                }
                th = zq;
            }
        });
    }

    println!("\n== compressed artifacts: pack/unpack throughput + packed GEMM ==");
    {
        // the artifact subsystem's two costs: the one-time encode (scale
        // recovery + bit-packing) and the steady-state packed consumers
        // (decode, streaming dequant GEMM, survivor-only N:M GEMM) — each
        // against the dense baseline it replaces
        use awp::artifact::PackedLinear;
        use awp::proj::{NmStructured, ProjScratch, Projection};
        use awp::quant::project_qmax;

        let (m, k, n) = (256usize, 256usize, 256usize);
        let bytes = (m * k * 4) as f64;
        let b = Matrix::randn(k, n, 41);

        let qtheta = project_qmax(&Matrix::randn(m, k, 40), 15.0, 32);
        let qspec = CompressionSpec::quant(4, 32);
        let r = bench(&format!("pack int4/g32 {m}x{k}"), 1.0, || {
            PackedLinear::encode(&qtheta, &qspec);
        });
        println!("    ↳ {:.1} MB/s dense-in", bytes / r.median_s / 1e6);
        let qpacked = PackedLinear::encode(&qtheta, &qspec);
        let r = bench(&format!("unpack int4/g32 {m}x{k}"), 1.0, || {
            qpacked.decode();
        });
        println!("    ↳ {:.1} MB/s dense-out ({} -> {} bytes on disk)",
                 bytes / r.median_s / 1e6, qpacked.dense_bytes(),
                 qpacked.packed_bytes());

        let mut stheta = Matrix::randn(m, k, 42);
        NmStructured::new(2, 4).project_rows(&mut stheta, &mut ProjScratch::new());
        let sspec = CompressionSpec::structured_nm(2, 4);
        bench(&format!("pack 2:4 mask {m}x{k}"), 1.0, || {
            PackedLinear::encode(&stheta, &sspec);
        });
        let spacked = PackedLinear::encode(&stheta, &sspec);
        bench(&format!("unpack 2:4 mask {m}x{k}"), 1.0, || {
            spacked.decode();
        });

        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let r = bench(&format!("dense matmul {m}x{k}x{n}"), 1.0, || {
            awp::tensor::ops::matmul(&qtheta, &b);
        });
        println!("    ↳ {:.1} GFLOP/s", r.gflops(flops));
        let r = bench(&format!("packed int4 GEMM {m}x{k}x{n}"), 1.0, || {
            qpacked.matmul(&b);
        });
        println!("    ↳ {:.1} GFLOP/s (dequant-on-the-fly)", r.gflops(flops));
        let r = bench(&format!("dense matmul 2:4 {m}x{k}x{n}"), 1.0, || {
            awp::tensor::ops::matmul(&stheta, &b);
        });
        println!("    ↳ {:.1} GFLOP/s", r.gflops(flops));
        let r = bench(&format!("packed 2:4 sparse GEMM {m}x{k}x{n}"), 1.0, || {
            spacked.matmul_sparse(&b);
        });
        println!("    ↳ {:.1} GFLOP/s dense-equivalent (survivors only)",
                 r.gflops(flops));
    }

    println!("\n== native inference: dense vs packed forward pass ==");
    {
        // the serving path: one eval window through the native transformer
        // with dense f32 sites vs the same weights executed straight off
        // their packed representations (streaming dequant / survivor-only
        // GEMMs) — the outputs are bit-identical, so this measures the
        // pure cost of on-the-fly decode
        use awp::artifact::PackedLinear;
        use awp::infer::{NativeModel, SiteWeights};
        use awp::model::{sites, ModelConfig};
        use awp::proj::ProjScratch;

        let cfg = ModelConfig {
            name: "bench".into(), vocab: 256, d_model: 128, n_heads: 4,
            n_layers: 2, d_ff: 256, seq_len: 32, batch: 2, decode_len: 16,
            rope_theta: 1e4,
        };
        let ck = awp::trainer::init_checkpoint(&cfg, 50);
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq_len)
            .map(|i| (i * 31 % cfg.vocab) as i32)
            .collect();
        for (label, spec) in [("int4/g32", CompressionSpec::quant(4, 32)),
                              ("2:4", CompressionSpec::structured24())] {
            let mut dense_sites = Vec::new();
            let mut packed_sites = Vec::new();
            for s in sites::enumerate_sites(&cfg) {
                let mut theta = ck.matrix(&s.param).unwrap();
                spec.projection(theta.cols)
                    .project_rows(&mut theta, &mut ProjScratch::new());
                let packed = PackedLinear::encode(&theta, &spec);
                packed_sites.push((s.param.clone(), SiteWeights::packed(packed)));
                dense_sites.push((s.param, SiteWeights::Dense(theta)));
            }
            let dense = NativeModel::with_site_weights(&ck, dense_sites).unwrap();
            let packed = NativeModel::with_site_weights(&ck, packed_sites).unwrap();
            bench(&format!("native fwd dense {label} 2x32"), 1.0, || {
                dense.forward(&tokens, cfg.batch, cfg.seq_len).unwrap();
            });
            bench(&format!("native fwd packed {label} 2x32"), 1.0, || {
                packed.forward(&tokens, cfg.batch, cfg.seq_len).unwrap();
            });
        }
    }

    println!("\n== §3 cost scaling: AWP per-iteration GEMM vs Hessian inverse ==");
    for &d in &[128usize, 256, 512, 1024] {
        let w = Matrix::randn(128, d, 7);
        // theta must differ from w everywhere or the residual zero-skip
        // fast-path turns the bench into a no-op
        let theta = Matrix::randn(128, d, 9);
        let c = Matrix::randn_gram(d, 8);
        let r = bench(&format!("awp pgd_step 128x{d}"), 0.5, || {
            awp::tensor::ops::pgd_step(&w, &theta, &c, 0.1);
        });
        let flops = 2.0 * 128.0 * (d as f64) * (d as f64);
        println!("    ↳ {:.1} GFLOP/s", r.gflops(flops));
        bench(&format!("hessian-inverse chol {d}"), 0.5, || {
            awp::compress::obs::hinv_upper_chol(&c, 0.01);
        });
    }
}
