//! Per-layer compression cost across methods and shapes — the paper's §3
//! complexity claim: AWP's `O(d_out·d_in²)` GEMM iterations vs the
//! Hessian-inverse (`O(d_in³)` + column sweeps) of SparseGPT/GPTQ, all on
//! the same substrates. One bench per paper table's method set.
//!
//! ```bash
//! cargo bench --bench compression
//! ```

use awp::compress::traits::{CompressionSpec, LayerCompressor};
use awp::compress::{
    awq::AwqQuant, gptq::Gptq, magnitude::MagnitudePrune, rtn::RtnQuant,
    sequential::SequentialCombo, sparsegpt::SparseGpt, wanda::WandaPrune, AwpCpu,
};
use awp::tensor::Matrix;
use awp::util::bench::bench;

fn main() {
    // the three weight-shape classes of the `small` model
    let shapes = [(256usize, 256usize), (1024, 256), (256, 1024)];

    println!("== Table 1/2 methods: pruning at 50% ==");
    for &(m, k) in &shapes {
        let w = Matrix::randn(m, k, 1);
        let c = Matrix::randn_gram(k, 2);
        let spec = CompressionSpec::prune(0.5);
        let methods: Vec<(&str, Box<dyn LayerCompressor>)> = vec![
            ("magnitude", Box::new(MagnitudePrune)),
            ("wanda", Box::new(WandaPrune)),
            ("sparsegpt", Box::new(SparseGpt::default())),
            ("awp-cpu", Box::<AwpCpu>::default()),
        ];
        for (name, c_) in methods {
            bench(&format!("prune50 {name} {m}x{k}"), 1.0, || {
                c_.compress(&w, &c, &spec).unwrap();
            });
        }
        println!();
    }

    println!("== Table 3 methods: INT4 quantization (group 32) ==");
    for &(m, k) in &shapes[..2] {
        let w = Matrix::randn(m, k, 3);
        let c = Matrix::randn_gram(k, 4);
        let spec = CompressionSpec::quant(4, 32);
        let methods: Vec<(&str, Box<dyn LayerCompressor>)> = vec![
            ("rtn", Box::new(RtnQuant)),
            ("gptq", Box::new(Gptq::default())),
            ("awq", Box::new(AwqQuant::default())),
            ("awp-cpu", Box::<AwpCpu>::default()),
        ];
        for (name, c_) in methods {
            bench(&format!("quant4 {name} {m}x{k}"), 1.0, || {
                c_.compress(&w, &c, &spec).unwrap();
            });
        }
        println!();
    }

    println!("== Table 4/5 methods: joint 50% + INT4 ==");
    {
        let (m, k) = (256, 256);
        let w = Matrix::randn(m, k, 5);
        let c = Matrix::randn_gram(k, 6);
        let spec = CompressionSpec::joint(0.5, 4, 32);
        let methods: Vec<(&str, Box<dyn LayerCompressor>)> = vec![
            ("awq+wanda", Box::new(SequentialCombo::awq_then_wanda())),
            ("wanda+awq", Box::new(SequentialCombo::wanda_then_awq())),
            ("awp-cpu", Box::<AwpCpu>::default()),
        ];
        for (name, c_) in methods {
            bench(&format!("joint50+int4 {name} {m}x{k}"), 1.5, || {
                c_.compress(&w, &c, &spec).unwrap();
            });
        }
    }

    println!("\n== §3 cost scaling: AWP per-iteration GEMM vs Hessian inverse ==");
    for &d in &[128usize, 256, 512, 1024] {
        let w = Matrix::randn(128, d, 7);
        // theta must differ from w everywhere or the residual zero-skip
        // fast-path turns the bench into a no-op
        let theta = Matrix::randn(128, d, 9);
        let c = Matrix::randn_gram(d, 8);
        let r = bench(&format!("awp pgd_step 128x{d}"), 0.5, || {
            awp::tensor::ops::pgd_step(&w, &theta, &c, 0.1);
        });
        let flops = 2.0 * 128.0 * (d as f64) * (d as f64);
        println!("    ↳ {:.1} GFLOP/s", r.gflops(flops));
        bench(&format!("hessian-inverse chol {d}"), 0.5, || {
            awp::compress::obs::hinv_upper_chol(&c, 0.01);
        });
    }
}
