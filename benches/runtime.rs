//! PJRT runtime benches: executable latency for every AOT program class
//! plus actor-channel overhead — the L3↔artifact boundary of the perf
//! pass. Skips cleanly when `artifacts/` is missing.
//!
//! ```bash
//! make artifacts && cargo bench --bench runtime
//! ```

use std::sync::Arc;

use awp::compress::awp::AwpBackend;
use awp::compress::CpuBackend;
use awp::proj::RowTopK;
use awp::runtime::{HloBackend, HostTensor, Manifest, Runtime};
use awp::tensor::Matrix;
use awp::trainer::init_checkpoint;
use awp::util::bench::bench;
use awp::util::Rng;

fn main() -> anyhow::Result<()> {
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("no artifacts/ — run `make artifacts` first; skipping runtime bench");
        return Ok(());
    };
    let manifest = Arc::new(manifest);
    let runtime = Runtime::start()?;
    let handle = runtime.handle();

    println!("== AWP chunk programs (8 PGD iterations per call) vs CPU backend ==");
    let hlo = HloBackend::new(handle.clone(), manifest.clone());
    let cpu = CpuBackend;
    for &(m, k) in &[(256usize, 256usize), (1024, 256), (256, 1024)] {
        let w = Matrix::randn(m, k, 0);
        let th = Matrix::zeros(m, k);
        let c = Matrix::randn_gram(k, 1);
        let eta = (2.0 / c.frob_norm()) as f32;
        let proj = RowTopK::new(k / 2);
        bench(&format!("hlo awp_prune chunk8 {m}x{k}"), 1.5, || {
            hlo.step_chunk_from(&w, &th, &c, eta, &proj, 8).unwrap();
        });
        bench(&format!("cpu awp_prune chunk8 {m}x{k}"), 1.5, || {
            cpu.step_chunk_from(&w, &th, &c, eta, &proj, 8).unwrap();
        });
    }

    println!("\n== model programs ({} geometry) ==", "small");
    let entry = manifest.model("small")?;
    let mcfg = &entry.config;
    let ck = init_checkpoint(mcfg, 0);
    let params: Vec<HostTensor> = ck
        .tensors
        .iter()
        .map(|(_, s, d)| HostTensor::vec_f32(d.clone(), s.clone()))
        .collect();
    let mut rng = Rng::new(2);
    let tokens: Vec<i32> = (0..mcfg.batch * mcfg.seq_len)
        .map(|_| rng.below(256) as i32)
        .collect();
    let tok_tensor = HostTensor::vec_i32(tokens, vec![mcfg.batch, mcfg.seq_len]);

    let eval_path = manifest.model_program_path("small", "eval_loss")?;
    let mut args = params.clone();
    args.push(tok_tensor.clone());
    bench("eval_loss small (4x128)", 2.0, || {
        handle.execute("eval_loss", eval_path.clone(), args.clone()).unwrap();
    });

    let train_path = manifest.model_program_path("small", "train_step")?;
    let zeros: Vec<HostTensor> = params
        .iter()
        .map(|t| HostTensor::vec_f32(vec![0.0; t.len()], t.shape().to_vec()))
        .collect();
    let mut targs = params.clone();
    targs.extend(zeros.iter().cloned());
    targs.extend(zeros.iter().cloned());
    targs.push(tok_tensor.clone());
    targs.push(HostTensor::scalar_f32(1e-3));
    targs.push(HostTensor::scalar_f32(0.0));
    bench("train_step small (4x128)", 2.0, || {
        handle.execute("train_step", train_path.clone(), targs.clone()).unwrap();
    });

    let calib_path = manifest.model_program_path("small", "calib_capture")?;
    let mut cargs = params.clone();
    cargs.push(tok_tensor);
    bench("calib_capture small (4x128)", 2.0, || {
        handle.execute("calib_capture", calib_path.clone(), cargs.clone()).unwrap();
    });

    println!("\n== actor-channel overhead (marshal + queue, no compute) ==");
    // smallest program available: decode_step on tiny
    let tiny = manifest.model("tiny")?;
    let dpath = manifest.model_program_path("tiny", "decode_step")?;
    let tck = init_checkpoint(&tiny.config, 0);
    let mut dargs: Vec<HostTensor> = tck
        .tensors
        .iter()
        .map(|(_, s, d)| HostTensor::vec_f32(d.clone(), s.clone()))
        .collect();
    dargs.push(HostTensor::vec_i32(vec![65; tiny.config.decode_len],
                                   vec![1, tiny.config.decode_len]));
    bench("decode_step tiny (1x64)", 1.0, || {
        handle.execute("decode_step", dpath.clone(), dargs.clone()).unwrap();
    });

    let stats = handle.stats()?;
    println!("\nruntime totals: {} executions, exec {:.1}s, compile {:.1}s",
             stats.executions, stats.exec_seconds, stats.compile_seconds);
    Ok(())
}
