"""Fused PGD gradient-step kernel: ``Z = Theta + eta * (W - Theta) @ C``.

This is the dominant cost of AWP's Algorithm 1 — the paper notes the whole
method is ``O(d_out * d_in^2)`` per iteration, i.e. one residual-GEMM against
the activation Gram matrix ``C``, and stresses that (unlike OBC/SparseGPT/GPTQ)
it needs neither an SVD of ``C`` nor a Hessian inverse.

TPU mapping (DESIGN.md §8): the CUDA formulation ("run rows in parallel on the
GPU") becomes a 3-d grid over ``(M/Tm, N/Tn, K/Tk)`` output/contraction tiles.

* ``W`` and ``Theta`` tiles stream HBM->VMEM once per ``(m, k)``; the residual
  ``W - Theta`` is formed *in VMEM* (never materialised in HBM — on an A100 the
  paper's implementation would burn HBM bandwidth on it).
* the ``(Tk, Tn)`` tile of ``C`` feeds the MXU systolic array; tile sizes
  default to 128 jointly with the lane/sublane layout so the 128x128 MXU is
  filled (f32 here; bf16 halves VMEM and doubles MXU rate if numerics allow).
* the epilogue ``Theta + eta * acc`` fuses into the same kernel on the last
  ``k`` step, so ``Z`` is written to HBM exactly once.

VMEM footprint per step (f32, T=128): W + Theta_k + C + Theta_n + out tiles =
5 * 128*128*4 B = 320 KiB, comfortably inside the ~16 MiB/core budget; the
pipelined double-buffering Pallas inserts doubles the streamed tiles to
~512 KiB. MXU utilisation estimate: the inner ``(128,128)x(128,128)`` matmul
is exactly one MXU-shaped contraction per grid step, so the kernel is
compute-bound for d_in >= 512 (arithmetic intensity ~64 FLOP/B at T=128).

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(dim: int, preferred: int) -> int:
    """Largest tile <= preferred that divides dim (dims here are powers-of-two
    multiples of 64 for the model shape classes; tests sweep odd sizes too)."""
    t = min(preferred, dim)
    while dim % t != 0:
        t -= 1
    return t


def _pgd_kernel(nk: int, eta_ref, w_ref, tk_ref, c_ref, tn_ref, o_ref):
    """One (m, n, k) grid step.

    eta_ref: (1, 1) scalar  | w_ref, tk_ref: (Tm, Tk) tiles of W, Theta
    c_ref:   (Tk, Tn) tile of C | tn_ref: (Tm, Tn) tile of Theta | o_ref: out.

    The output tile for a fixed (m, n) stays resident in VMEM across the k
    loop (its index map ignores k), so we accumulate partial products into it
    directly: init to Theta on k == 0, add eta * (W - Theta)_mk @ C_kn each
    step. After the last k step it holds Z = Theta + eta * (W - Theta) @ C.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = tn_ref[...]

    resid = w_ref[...] - tk_ref[...]  # formed in VMEM, never hits HBM
    # MXU contraction; preferred_element_type keeps the accumulator f32.
    part = jnp.dot(resid, c_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += eta_ref[0, 0] * part


def pgd_step(w, theta, c, eta, *, tile_m: int = 128, tile_n: int = 128,
             tile_k: int = 128, interpret: bool = True):
    """``theta + eta * (w - theta) @ c`` with a fused Pallas kernel.

    Args:
      w, theta: ``(d_out, d_in)`` f32 — original and current weights.
      c: ``(d_in, d_in)`` f32 — activation Gram matrix ``X X^T / n``.
      eta: scalar f32 step size (traced; may vary at runtime).
      tile_*: requested VMEM tile sizes; shrunk to divide the actual dims.

    Returns:
      ``(d_out, d_in)`` f32 ``Z`` — the pre-projection PGD iterate.
    """
    m, kdim = w.shape
    k2, n = c.shape
    assert kdim == k2 and k2 == n, f"C must be (d_in,d_in), got {c.shape}"
    assert theta.shape == w.shape
    tm = _pick_tile(m, tile_m)
    tn = _pick_tile(n, tile_n)
    tk = _pick_tile(kdim, tile_k)
    nk = kdim // tk
    eta_arr = jnp.asarray(eta, jnp.float32).reshape(1, 1)

    grid = (m // tm, n // tn, nk)
    return pl.pallas_call(
        partial(_pgd_kernel, nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda mi, ni, ki: (0, 0)),      # eta
            pl.BlockSpec((tm, tk), lambda mi, ni, ki: (mi, ki)),  # W
            pl.BlockSpec((tm, tk), lambda mi, ni, ki: (mi, ki)),  # Theta (k)
            pl.BlockSpec((tk, tn), lambda mi, ni, ki: (ki, ni)),  # C
            pl.BlockSpec((tm, tn), lambda mi, ni, ki: (mi, ni)),  # Theta (n)
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(eta_arr, w, theta, c, theta)
