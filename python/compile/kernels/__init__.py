"""Layer-1 Pallas kernels for the AWP hot path.

Two kernels cover the per-iteration cost of Algorithm 1 in the paper:

* :func:`pgd_step` — the fused gradient step ``Z = Theta + eta * (W - Theta) @ C``
  (the ``O(d_out * d_in^2)`` term the paper calls out as the dominant cost).
* :func:`quant_project` — the grouped affine INT-grid projection
  ``Proj_{C_INTb}(Z)`` used for quantization and joint compression.

Both are authored for TPU (BlockSpec HBM->VMEM schedule, MXU-shaped tiles)
but lowered with ``interpret=True`` so the CPU PJRT plugin can execute the
resulting HLO; see DESIGN.md §8 for the hardware-adaptation story.

Pure-jnp oracles live in :mod:`compile.kernels.ref`.
"""

from .pgd_step import pgd_step
from .quant_project import quant_project
from . import ref

__all__ = ["pgd_step", "quant_project", "ref"]
