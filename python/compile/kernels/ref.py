"""Pure-jnp oracles for every L1 kernel and L2 projection.

These are the correctness ground truth: python/tests asserts the Pallas
kernels match these to float tolerance across hypothesis-swept shapes, and
the Rust test-suite cross-checks its pure-CPU AWP implementation against
vectors generated from these (see rust/tests/).
"""

import jax.numpy as jnp
import jax


def pgd_step_ref(w, theta, c, eta):
    """``theta + eta * (w - theta) @ c`` — oracle for kernels.pgd_step."""
    return theta + eta * (w - theta) @ c


def quant_project_ref(z, qmax, *, group: int = 32):
    """Grouped affine round-to-nearest — oracle for kernels.quant_project."""
    m, d = z.shape
    g = z.reshape(m, d // group, group)
    lo = jnp.min(g, axis=-1, keepdims=True)
    hi = jnp.max(g, axis=-1, keepdims=True)
    scale = (hi - lo) / qmax
    safe = jnp.where(scale > 0.0, scale, 1.0)
    zp = jnp.round(-lo / safe)
    q = jnp.clip(jnp.round(g / safe) + zp, 0.0, qmax)
    deq = jnp.where(scale > 0.0, (q - zp) * safe, lo)
    return deq.reshape(m, d)


def topk_rows_ref(z, k):
    """Row-wise hard threshold: keep the k largest-|.| entries of each row.

    Oracle for the L2 ``topk_rows`` projection (compile/awp.py). ``k`` is a
    traced scalar; implemented by sorting |z| per row and thresholding at the
    k-th largest value, which keeps >= k entries on exact ties (measure-zero
    for float data; tests use tie-free inputs for the exact-k property).
    """
    absz = jnp.abs(z)
    srt = jnp.sort(absz, axis=1)[:, ::-1]  # descending
    kc = jnp.clip(k, 1, z.shape[1])
    kth = jax.lax.dynamic_slice_in_dim(srt, kc - 1, 1, axis=1)
    mask = absz >= kth
    return jnp.where(mask, z, 0.0)


def awp_loss_ref(w, theta, c):
    """Activation-aware loss ``||(W - Theta) C^{1/2}||_F^2`` WITHOUT forming
    ``C^{1/2}``: equals ``tr[(W-Theta) C (W-Theta)^T] = sum(R * (R @ C))``.

    This identity (paper Appendix B) is what lets both the python and rust
    sides track Figure-1's loss series with one GEMM instead of an SVD.
    """
    r = w - theta
    return jnp.maximum(jnp.sum(r * (r @ c)), 0.0)
