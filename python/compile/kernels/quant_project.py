"""Grouped affine INT-grid projection kernel: ``Proj_{C_INTb}(Z)``.

The paper's quantization constraint set is a per-group affine INT grid
(group size 128 on Llama-scale models; 32 here to match the smaller d_in —
the grouping *structure* is what matters). The projection of ``Z`` onto the
grid is exactly round-to-nearest after per-group rescaling:

    scale = (max - min) / qmax            (qmax = 2^bits - 1)
    zp    = round(-min / scale)
    q     = clamp(round(z / scale) + zp, 0, qmax)
    proj  = (q - zp) * scale

``qmax`` is passed as a traced scalar so ONE compiled executable serves
INT2/INT3/INT4/INT8 — the Rust coordinator picks the bit-width at runtime.

TPU mapping: purely elementwise + small per-group reductions -> VPU work, no
MXU. The grid tiles rows only; each kernel invocation sees a ``(Tm, d_in)``
slab reshaped to ``(Tm, n_groups, group)`` in VMEM registers. VMEM per step
(f32, Tm=256, d_in=1536): in + out = 2 * 256*1536*4 B = 3 MiB — fine.

interpret=True for CPU-PJRT executability (see pgd_step.py).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(group: int, qmax_ref, z_ref, o_ref):
    z = z_ref[...]
    tm, d = z.shape
    g = z.reshape(tm, d // group, group)
    qmax = qmax_ref[0, 0]
    lo = jnp.min(g, axis=-1, keepdims=True)
    hi = jnp.max(g, axis=-1, keepdims=True)
    scale = (hi - lo) / qmax
    # Flat group (hi == lo) -> scale 0; guard the divide, output collapses
    # to lo which IS the group's single grid point.
    safe = jnp.where(scale > 0.0, scale, 1.0)
    zp = jnp.round(-lo / safe)
    q = jnp.clip(jnp.round(g / safe) + zp, 0.0, qmax)
    deq = (q - zp) * safe
    deq = jnp.where(scale > 0.0, deq, lo)
    o_ref[...] = deq.reshape(tm, d)


def quant_project(z, qmax, *, group: int = 32, tile_m: int = 256,
                  interpret: bool = True):
    """Project ``z`` onto the per-group affine INT grid with ``qmax`` levels.

    Args:
      z: ``(d_out, d_in)`` f32; ``d_in`` must be a multiple of ``group``.
      qmax: traced scalar f32 = ``2^bits - 1`` (e.g. 15.0 for INT4).
      group: static quantization group size along ``d_in``.

    Returns:
      ``(d_out, d_in)`` f32 — nearest point of the INT grid (dequantized).
    """
    m, d = z.shape
    assert d % group == 0, f"d_in={d} not a multiple of group={group}"
    tm = min(tile_m, m)
    while m % tm != 0:
        tm -= 1
    qmax_arr = jnp.asarray(qmax, jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        partial(_quant_kernel, group),
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda mi: (0, 0)),   # qmax
            pl.BlockSpec((tm, d), lambda mi: (mi, 0)),  # Z row slab
        ],
        out_specs=pl.BlockSpec((tm, d), lambda mi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(qmax_arr, z)
