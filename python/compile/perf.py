"""Perf analysis for L1 (Pallas kernel structure) and L2 (lowered HLO).

Run:  cd python && python -m compile.perf

interpret=True gives CPU-numpy timings which are NOT a TPU proxy, so L1 is
optimized *structurally*: this tool reports, per candidate tile config,

* VMEM working set (streamed tiles + resident output tile, double-buffered)
  against the ~16 MiB/core budget;
* MXU-shape fit (tiles vs the 128x128 systolic array) and the implied
  utilization of each contraction step;
* arithmetic intensity (FLOP per HBM byte) → compute- vs memory-bound.

For L2 it runs XLA's cost analysis on the lowered AWP chunk program and the
train step: total FLOPs, bytes accessed, and the FLOP:byte ratio — the
"no redundant recomputation / fused epilogue" check in DESIGN.md §9.
Numbers land in EXPERIMENTS.md §Perf.
"""

import jax
import jax.numpy as jnp
from functools import partial

from . import awp as awp_mod
from . import model as model_mod
from .model import MODEL_SIZES


def l1_tile_report(shapes, tiles):
    print("== L1 pgd_step tile analysis (f32) ==")
    print(f"{'shape':>12} {'tile':>12} {'VMEM KiB':>9} {'MXU fill':>9} "
          f"{'AI F/B':>7}  note")
    budget = 16 * 1024  # KiB per TPU core
    for (m, k) in shapes:
        n = k
        for (tm, tn, tk) in tiles:
            tm_, tn_, tk_ = min(tm, m), min(tn, n), min(tk, k)
            # resident: out tile; streamed (double-buffered x2): W, Θk, C, Θn
            resident = tm_ * tn_ * 4
            streamed = 2 * (tm_ * tk_ + tm_ * tk_ + tk_ * tn_ + tm_ * tn_) * 4
            vmem_kib = (resident + streamed) / 1024
            # MXU fill: each (tm x tk) @ (tk x tn) step vs 128x128 PEs
            fill = min(tm_, 128) * min(tn_, 128) / (128 * 128)
            # arithmetic intensity per grid step: 2*tm*tn*tk FLOP over
            # (W + Θk + C tiles) HBM reads + out write amortised over k-steps
            flop = 2 * tm_ * tn_ * tk_
            bytes_ = (2 * tm_ * tk_ + tk_ * tn_) * 4
            ai = flop / bytes_
            note = "OK" if vmem_kib <= budget else "OVER VMEM"
            if fill < 1.0:
                note += ", MXU under-filled"
            print(f"{m:>5}x{k:<6} {f'{tm_}/{tn_}/{tk_}':>12} {vmem_kib:>9.0f} "
                  f"{fill:>8.0%} {ai:>7.1f}  {note}")
    print()


def l2_cost(name, fn, args):
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = ca.get("flops", float("nan"))
    bytes_ = ca.get("bytes accessed", float("nan"))
    print(f"{name:>28}: {flops/1e9:8.3f} GFLOP  {bytes_/1e6:8.1f} MB  "
          f"AI {flops/max(bytes_,1):6.1f} F/B")
    return flops, bytes_


def main():
    shapes = [(256, 256), (1024, 256), (256, 1024), (1536, 384), (384, 1536)]
    tiles = [(64, 64, 64), (128, 128, 128), (256, 128, 128), (128, 256, 128)]
    l1_tile_report(shapes, tiles)

    print("== L2 XLA cost analysis (lowered + compiled programs) ==")
    f32 = lambda s: jax.ShapeDtypeStruct(s, jnp.float32)
    i32 = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)

    for (m, k) in [(256, 256), (256, 1024)]:
        w, c = f32((m, k)), f32((k, k))
        flops, _ = l2_cost(
            f"awp_prune chunk8 {m}x{k}",
            partial(awp_mod.awp_prune_chunk, chunk=8),
            [w, w, c, f32(()), i32(())])
        # XLA's cost analysis counts a while-loop body ONCE regardless of
        # trip count, so compare against one PGD body + the stats GEMM.
        ideal_once = 2 * m * k * k + 2 * m * k * k
        print(f"{'':>28}  body-once ideal {ideal_once/1e9:8.3f} GFLOP  "
              f"overhead {flops/ideal_once - 1:+.1%}")

    cfg = MODEL_SIZES["small"]
    spec = model_mod.param_spec(cfg)
    pshapes = [f32(s) for _, s in spec]
    tokens = i32((cfg.batch, cfg.seq_len))
    scalar = f32(())
    l2_cost("train_step small", model_mod.make_train_step(cfg),
            pshapes * 3 + [tokens, scalar, scalar])
    l2_cost("eval_loss small", model_mod.make_eval_loss(cfg),
            pshapes + [tokens])
    l2_cost("calib_capture small", model_mod.make_calib_capture(cfg),
            pshapes + [tokens])


if __name__ == "__main__":
    main()
