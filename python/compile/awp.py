"""Layer-2 AWP programs: chunked projected-gradient-descent steps.

Algorithm 1 of the paper, specialised per constraint set:

* ``awp_prune_chunk``  — Proj is row-wise hard thresholding (C_row, eq. 5);
* ``awp_quant_chunk``  — Proj is the grouped INT grid (C_INTb);
* ``awp_joint_chunk``  — Proj_INT(Proj_row(Z)), the paper's §4.3 composition.

Each program runs ``chunk`` PGD iterations inside a ``lax.fori_loop`` (one
HLO while-loop — no per-iteration host round-trip) and returns the iterate
plus the two scalars the Rust coordinator needs:

* ``rel_grad``  — ``||(W-Theta)C||_F / ||W||_F`` — the paper's stopping
  criterion (threshold 1e-4, or max-iteration cap);
* ``rel_loss``  — ``||(W-Theta)C^{1/2}||_F / ||W||_F`` — Figure 1's series,
  computed via the trace identity (Appendix B) with no SVD.

``k`` (sparsity per row) and ``qmax`` (INT levels) are *traced* scalars, so a
single compiled executable per weight-shape class serves every pruning ratio
and bit-width; the Rust side drives the §4.3 ramp schedule by simply varying
``k`` call-to-call.
"""

import jax
import jax.numpy as jnp
from jax import lax

from . import kernels
from .kernels.ref import topk_rows_ref as topk_rows  # L2 projection (XLA sort)


def _stats(w, theta, c):
    r = w - theta
    g = r @ c
    wn = jnp.sqrt(jnp.sum(w * w)) + 1e-30
    rel_grad = jnp.sqrt(jnp.sum(g * g)) / wn
    rel_loss = jnp.sqrt(jnp.maximum(jnp.sum(r * g), 0.0)) / wn
    return rel_grad, rel_loss


def awp_prune_chunk(w, theta, c, eta, k, *, chunk: int = 8):
    """``chunk`` IHT iterations: Theta <- H_k(Theta + eta (W - Theta) C)."""

    def body(_, th):
        z = kernels.pgd_step(w, th, c, eta)
        return topk_rows(z, k)

    theta = lax.fori_loop(0, chunk, body, theta)
    rel_grad, rel_loss = _stats(w, theta, c)
    return theta, rel_grad, rel_loss


def awp_quant_chunk(w, theta, c, eta, qmax, *, chunk: int = 8,
                    group: int = 32):
    """``chunk`` PGD iterations projected onto the grouped INT grid."""

    def body(_, th):
        z = kernels.pgd_step(w, th, c, eta)
        return kernels.quant_project(z, qmax, group=group)

    theta = lax.fori_loop(0, chunk, body, theta)
    rel_grad, rel_loss = _stats(w, theta, c)
    return theta, rel_grad, rel_loss


def awp_joint_chunk(w, theta, c, eta, k, qmax, *, chunk: int = 8,
                    group: int = 32):
    """Joint pruning + quantization: Proj_INT(Proj_row(Z)) per iteration.

    Matches §4.3: prune Z first (obtaining the sparsity mask implicitly),
    quantize the pruned iterate, then re-apply the mask so zeros survive
    quantization (the INT grid's zero-point may not be exact zero).

    When ``qmax <= 0`` the quantization projection is skipped — the Rust
    coordinator uses this for the first half of the §4.3 schedule (pure
    pruning with a linearly ramped ratio) without a separate executable.
    """

    def body(_, th):
        z = kernels.pgd_step(w, th, c, eta)
        zp = topk_rows(z, k)
        mask = (zp != 0.0).astype(zp.dtype)
        zq = kernels.quant_project(zp, jnp.maximum(qmax, 1.0), group=group)
        zq = zq * mask
        return jnp.where(qmax > 0.0, zq, zp)

    theta = lax.fori_loop(0, chunk, body, theta)
    rel_grad, rel_loss = _stats(w, theta, c)
    return theta, rel_grad, rel_loss
