"""Layer-2 JAX model: the transformer LM substrate.

The paper compresses Llama-family checkpoints; we cannot ship those, so the
repo trains its own pre-norm transformer LMs (DESIGN.md §2) and compresses
them. Everything a Llama block exposes to layer-wise compression is here:
RMSNorm, RoPE causal attention with separate q/k/v/o projections, a SiLU MLP,
and a tied embedding head — i.e. four linear weight sites per block with the
three shape classes ``(d,d)``, ``(ff,d)``, ``(d,ff)``.

Exported programs (lowered by compile/aot.py, executed from Rust):

* ``train_step``    — AdamW fwd/bwd update, donated params/opt-state.
* ``eval_loss``     — summed next-token NLL + token count (perplexity in Rust).
* ``calib_capture`` — per-site activation Gram updates ``X X^T`` (the ``C``
  matrices of eq. (3)), accumulated across batches by the Rust coordinator.
* ``decode_step``   — last-position logits for greedy generation.

Parameters cross the HLO boundary as a *flat list* in ``param_names()``
order; the same order is recorded in artifacts/manifest.json and used by
rust/src/model/store.rs. Python never runs at serving/compression time.
"""

from dataclasses import dataclass, field, asdict
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Config


@dataclass(frozen=True)
class ModelConfig:
    """Architecture + AOT batch geometry for one model size."""

    name: str
    vocab: int = 256          # byte-level tokenizer (rust/src/data)
    d_model: int = 256
    n_heads: int = 8
    n_layers: int = 4
    d_ff: int = 1024
    seq_len: int = 128        # train/eval/calib window
    batch: int = 4            # train/eval/calib batch
    decode_len: int = 64      # greedy-generation window
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_json(self) -> dict:
        return asdict(self)


# The three sizes stand in for the paper's model ladder (DESIGN.md §2):
# tiny ~ Llama-3.2-1B analog, small ~ Llama-2-7B / 3.1-8B analog,
# medium ~ Llama-2-13B analog.
MODEL_SIZES: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(name="tiny", d_model=128, n_heads=4, n_layers=4,
                        d_ff=512),
    "small": ModelConfig(name="small", d_model=256, n_heads=8, n_layers=4,
                         d_ff=1024),
    "medium": ModelConfig(name="medium", d_model=384, n_heads=8, n_layers=6,
                          d_ff=1536),
}


# ---------------------------------------------------------------------------
# Parameters

def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list — the HLO calling convention."""
    spec: List[Tuple[str, Tuple[int, ...]]] = [("embed", (cfg.vocab, cfg.d_model))]
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        spec += [
            (p + "ln1", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2", (cfg.d_model,)),
            (p + "w_up", (cfg.d_ff, cfg.d_model)),
            (p + "w_down", (cfg.d_model, cfg.d_ff)),
        ]
    spec.append(("ln_f", (cfg.d_model,)))
    return spec


def param_names(cfg: ModelConfig) -> List[str]:
    return [n for n, _ in param_spec(cfg)]


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Scaled-normal init (0.02 embeddings, 1/sqrt(fan_in) linears)."""
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jax.Array] = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "embed":
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[1]
            params[name] = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
    return params


def flatten(cfg: ModelConfig, params: Dict[str, jax.Array]) -> List[jax.Array]:
    return [params[n] for n in param_names(cfg)]


def unflatten(cfg: ModelConfig, flat) -> Dict[str, jax.Array]:
    names = param_names(cfg)
    assert len(flat) == len(names)
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# Forward

def _rmsnorm(x, g):
    return x * g * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _rope(x, theta: float):
    """Rotary embedding over (B, S, H, Dh)."""
    b, s, h, dh = x.shape
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.arange(s, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]            # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def forward(cfg: ModelConfig, params: Dict[str, jax.Array], tokens,
            capture: bool = False):
    """Run the LM; returns logits ``(B, S, V)`` and (optionally) the per-site
    activation Grams ``X X^T`` that define the compression objective."""
    b, s = tokens.shape
    x = params["embed"][tokens]                      # (B, S, d)
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.float32(-1e9)

    grams = {"attn_in": [], "attn_out_in": [], "mlp_in": [], "mlp_down_in": []}

    def gram(a):                                      # a: (B, S, D)
        flat = a.reshape(-1, a.shape[-1])
        return flat.T @ flat                          # (D, D), sum not mean

    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        h = _rmsnorm(x, params[p + "ln1"])
        if capture:
            grams["attn_in"].append(gram(h))
        q = (h @ params[p + "wq"].T).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = (h @ params[p + "wk"].T).reshape(b, s, cfg.n_heads, cfg.head_dim)
        v = (h @ params[p + "wv"].T).reshape(b, s, cfg.n_heads, cfg.head_dim)
        q = _rope(q, cfg.rope_theta)
        k = _rope(k, cfg.rope_theta)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(cfg.head_dim)
        att = jnp.where(mask[None, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, cfg.d_model)
        if capture:
            grams["attn_out_in"].append(gram(o))
        x = x + o @ params[p + "wo"].T

        h = _rmsnorm(x, params[p + "ln2"])
        if capture:
            grams["mlp_in"].append(gram(h))
        u = jax.nn.silu(h @ params[p + "w_up"].T)     # (B, S, ff)
        if capture:
            grams["mlp_down_in"].append(gram(u))
        x = x + u @ params[p + "w_down"].T

    x = _rmsnorm(x, params["ln_f"])
    logits = x @ params["embed"].T                    # tied head
    if capture:
        stacked = {k2: jnp.stack(v2) for k2, v2 in grams.items()}
        return logits, stacked
    return logits


def nll(cfg: ModelConfig, params, tokens):
    """Summed next-token negative log-likelihood + token count."""
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    picked = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.sum(picked), jnp.float32(tgt.size)


# ---------------------------------------------------------------------------
# Exported programs (flat-list calling convention)

ADAM_B1, ADAM_B2, ADAM_EPS, WEIGHT_DECAY = 0.9, 0.95, 1e-8, 0.01


def make_train_step(cfg: ModelConfig):
    """(params…, m…, v…, tokens, lr, step) -> (params'…, m'…, v'…, loss)."""
    n = len(param_names(cfg))
    names = param_names(cfg)

    def program(*args):
        flat_p, flat_m, flat_v = args[:n], args[n:2 * n], args[2 * n:3 * n]
        tokens, lr, step = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        params = unflatten(cfg, list(flat_p))

        def loss_fn(p):
            total, count = nll(cfg, p, tokens)
            return total / count

        loss, grads = jax.value_and_grad(loss_fn)(params)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - ADAM_B1 ** t
        bc2 = 1.0 - ADAM_B2 ** t
        new_p, new_m, new_v = [], [], []
        for name, p, m, v in zip(names, flat_p, flat_m, flat_v):
            g = grads[name]
            m2 = ADAM_B1 * m + (1 - ADAM_B1) * g
            v2 = ADAM_B2 * v + (1 - ADAM_B2) * g * g
            upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + ADAM_EPS)
            decay = 0.0 if name.endswith(("ln1", "ln2", "ln_f")) else WEIGHT_DECAY
            new_p.append(p - lr * (upd + decay * p))
            new_m.append(m2)
            new_v.append(v2)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    return program


def make_eval_loss(cfg: ModelConfig):
    """(params…, tokens) -> (sum_nll, token_count)."""
    n = len(param_names(cfg))

    def program(*args):
        params = unflatten(cfg, list(args[:n]))
        tokens = args[n]
        return nll(cfg, params, tokens)

    return program


def make_calib_capture(cfg: ModelConfig):
    """(params…, tokens) -> (attn_in, attn_out_in, mlp_in, mlp_down_in, count).

    Gram outputs are SUMS of ``x x^T`` over the batch's tokens, shaped
    ``(L, d, d)`` / ``(L, ff, ff)``; the Rust coordinator accumulates over
    calibration batches and divides by the total token count to form the
    paper's ``C = X X^T / n``.
    """
    n = len(param_names(cfg))

    def program(*args):
        params = unflatten(cfg, list(args[:n]))
        tokens = args[n]
        _, grams = forward(cfg, params, tokens, capture=True)
        count = jnp.float32(tokens.shape[0] * tokens.shape[1])
        return (grams["attn_in"], grams["attn_out_in"], grams["mlp_in"],
                grams["mlp_down_in"], count)

    return program


def make_decode_step(cfg: ModelConfig):
    """(params…, tokens(1, decode_len)) -> last-position logits (V,)."""
    n = len(param_names(cfg))

    def program(*args):
        params = unflatten(cfg, list(args[:n]))
        tokens = args[n]
        logits = forward(cfg, params, tokens)
        return (logits[0, -1, :],)

    return program
