"""AOT lowering: every L2 program -> HLO text + artifacts/manifest.json.

This is the ONLY entry point of the Python build path (``make artifacts``).
The Rust coordinator is self-contained afterwards: it loads the HLO text via
``HloModuleProto::from_text_file``, compiles on the PJRT CPU client, and
executes — Python never runs on the request path.

Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md). Programs are lowered with
``return_tuple=True`` and unwrapped with ``to_tuple()`` on the Rust side.

Emitted programs (see DESIGN.md §3 for the full table):

* per model size: ``train_step``, ``eval_loss``, ``calib_capture``,
  ``decode_step``;
* per weight-shape class (deduped across sizes): ``awp_prune_{m}x{k}``,
  ``awp_quant_{m}x{k}``, ``awp_joint_{m}x{k}`` (8 PGD iterations per call)
  and a ``chunk=1`` pruning variant ``awp_prune1_{m}x{k}`` for Figure 1's
  per-iteration loss series.
"""

import argparse
import json
import os
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import awp as awp_mod
from . import model as model_mod
from .model import MODEL_SIZES, ModelConfig

GROUP_SIZE = 32     # quantization group (paper: 128 @ llama scale)
AWP_CHUNK = 8       # PGD iterations folded into one executable call


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_program(fn, example_args, out_path: str) -> None:
    # keep_unused: the HLO calling convention is positional over the FULL
    # parameter list; without it jax DCEs dead inputs (e.g. ln_f in
    # calib_capture, whose logits are discarded) and the Rust side's
    # argument count no longer matches.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def model_programs(cfg: ModelConfig, out_dir: str, manifest: dict,
                   verbose: bool) -> None:
    spec = model_mod.param_spec(cfg)
    pshapes = [f32(s) for _, s in spec]
    tokens = i32((cfg.batch, cfg.seq_len))
    dec_tokens = i32((1, cfg.decode_len))
    scalar = f32(())

    progs = {
        "train_step": (model_mod.make_train_step(cfg),
                       pshapes * 3 + [tokens, scalar, scalar]),
        "eval_loss": (model_mod.make_eval_loss(cfg), pshapes + [tokens]),
        "calib_capture": (model_mod.make_calib_capture(cfg),
                          pshapes + [tokens]),
        "decode_step": (model_mod.make_decode_step(cfg),
                        pshapes + [dec_tokens]),
    }
    entry = {
        "config": cfg.to_json(),
        "params": [{"name": n, "shape": list(s)} for n, s in spec],
        "programs": {},
    }
    for pname, (fn, args) in progs.items():
        fname = f"{pname}_{cfg.name}.hlo.txt"
        t0 = time.time()
        lower_program(fn, args, os.path.join(out_dir, fname))
        if verbose:
            print(f"  {fname:40s} {time.time() - t0:6.1f}s", flush=True)
        entry["programs"][pname] = fname
    manifest["models"][cfg.name] = entry


def shape_classes():
    """All (d_out, d_in) weight shapes across model sizes, deduped."""
    shapes = set()
    for cfg in MODEL_SIZES.values():
        d, ff = cfg.d_model, cfg.d_ff
        shapes.update({(d, d), (ff, d), (d, ff)})
    return sorted(shapes)


def awp_programs(out_dir: str, manifest: dict, verbose: bool) -> None:
    manifest["awp"] = {"chunk": AWP_CHUNK, "group": GROUP_SIZE, "programs": {}}
    for (m, k) in shape_classes():
        w, th, c = f32((m, k)), f32((m, k)), f32((k, k))
        eta, kk, qmax = f32(()), i32(()), f32(())
        variants = {
            f"awp_prune_{m}x{k}": (
                partial(awp_mod.awp_prune_chunk, chunk=AWP_CHUNK),
                [w, th, c, eta, kk]),
            f"awp_prune1_{m}x{k}": (
                partial(awp_mod.awp_prune_chunk, chunk=1),
                [w, th, c, eta, kk]),
            f"awp_quant_{m}x{k}": (
                partial(awp_mod.awp_quant_chunk, chunk=AWP_CHUNK,
                        group=GROUP_SIZE),
                [w, th, c, eta, qmax]),
            # chunk=1 variants: the quantization / joint PGD can drift after
            # its early minimum (the INT grid is re-fit each projection), so
            # the Rust driver steps once at a time and keeps the best iterate
            # by rel_loss — mirroring the paper's small fixed budget (10 it).
            f"awp_quant1_{m}x{k}": (
                partial(awp_mod.awp_quant_chunk, chunk=1, group=GROUP_SIZE),
                [w, th, c, eta, qmax]),
            f"awp_joint_{m}x{k}": (
                partial(awp_mod.awp_joint_chunk, chunk=AWP_CHUNK,
                        group=GROUP_SIZE),
                [w, th, c, eta, kk, qmax]),
            f"awp_joint1_{m}x{k}": (
                partial(awp_mod.awp_joint_chunk, chunk=1, group=GROUP_SIZE),
                [w, th, c, eta, kk, qmax]),
        }
        for name, (fn, args) in variants.items():
            fname = f"{name}.hlo.txt"
            t0 = time.time()
            lower_program(fn, args, os.path.join(out_dir, fname))
            if verbose:
                print(f"  {fname:40s} {time.time() - t0:6.1f}s", flush=True)
            manifest["awp"]["programs"][name] = fname


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="tiny,small,medium",
                    help="comma-separated model sizes to lower")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    verbose = not args.quiet

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"models": {}, "format": "hlo-text", "version": 1}

    t0 = time.time()
    for size in args.models.split(","):
        cfg = MODEL_SIZES[size.strip()]
        if verbose:
            print(f"[aot] model programs: {cfg.name}", flush=True)
        model_programs(cfg, args.out_dir, manifest, verbose)

    if verbose:
        print("[aot] awp programs", flush=True)
    awp_programs(args.out_dir, manifest, verbose)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if verbose:
        n = sum(len(m["programs"]) for m in manifest["models"].values())
        n += len(manifest["awp"]["programs"])
        print(f"[aot] wrote {n} programs + manifest in "
              f"{time.time() - t0:.1f}s -> {args.out_dir}", flush=True)


if __name__ == "__main__":
    main()
