"""L2 AWP program semantics: convergence, constraint satisfaction, modes."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import awp
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def problem(seed, m=24, d=32):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    x = rng.normal(size=(d, 4 * d)) * np.exp(0.5 * rng.normal(size=(d, 1)))
    c = jnp.asarray(x @ x.T / (4 * d), jnp.float32)
    eta = jnp.float32(2.0 / float(jnp.linalg.norm(c)))  # paper's step size
    return w, c, eta


def wanda_init(w, c, k):
    """Wanda = magnitude of W scaled by sqrt(diag C), per-row top-k — the
    paper's pruning initialiser."""
    scores = jnp.abs(w) * jnp.sqrt(jnp.diag(c))[None, :]
    srt = jnp.sort(scores, axis=1)[:, ::-1]
    thr = srt[:, k - 1:k]
    return jnp.where(scores >= thr, w, 0.0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), ratio=st.sampled_from([0.5, 0.7, 0.9]))
def test_prune_reduces_activation_loss_vs_init(seed, ratio):
    """Core paper claim: AWP iterations improve on the Wanda starting point
    in the activation-aware metric (Fig. 1 behaviour)."""
    w, c, eta = problem(seed)
    k = max(1, int(round((1 - ratio) * w.shape[1])))
    th0 = wanda_init(w, c, k)
    loss0 = float(ref.awp_loss_ref(w, th0, c))
    th, _, _ = jax.jit(lambda *a: awp.awp_prune_chunk(*a, chunk=8))(
        w, th0, c, eta, jnp.int32(k))
    for _ in range(4):
        th, _, _ = jax.jit(lambda *a: awp.awp_prune_chunk(*a, chunk=8))(
            w, th, c, eta, jnp.int32(k))
    loss1 = float(ref.awp_loss_ref(w, th, c))
    assert loss1 <= loss0 * 1.001
    nnz = (np.asarray(th) != 0).sum(axis=1)
    assert (nnz <= k).all() or (nnz == k).all()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_prune_rel_grad_decreases(seed):
    w, c, eta = problem(seed)
    k = w.shape[1] // 2
    th = wanda_init(w, c, k)
    f = jax.jit(lambda *a: awp.awp_prune_chunk(*a, chunk=8))
    _, g1, _ = f(w, th, c, eta, jnp.int32(k))
    th2, _, _ = f(w, th, c, eta, jnp.int32(k))
    for _ in range(5):
        th2, g2, _ = f(w, th2, c, eta, jnp.int32(k))
    assert float(g2) <= float(g1) * 1.05


def test_quant_chunk_output_on_grid():
    w, c, eta = problem(0)
    th0 = ref.quant_project_ref(w, 15.0, group=32)
    th, g, l = jax.jit(lambda *a: awp.awp_quant_chunk(*a, chunk=8, group=32))(
        w, th0, c, jnp.float32(1.5 / float(jnp.linalg.norm(c))),
        jnp.float32(15.0))
    # output must be exactly re-projectable with zero change
    reproj = ref.quant_project_ref(th, 15.0, group=32)
    np.testing.assert_allclose(th, reproj, atol=1e-6)


def test_quant_chunk_improves_on_rtn():
    """AWP quantization beats plain round-to-nearest in activation loss
    (the Table-3 mechanism). Mirrors the Rust driver: chunk=1 steps with
    best-iterate tracking over the paper's 10-iteration budget — the raw
    PGD sequence may drift upward after its early minimum because the INT
    grid is re-fit at every projection."""
    w, c, eta = problem(3)
    th0 = ref.quant_project_ref(w, 7.0, group=32)   # INT3 RTN
    loss0 = float(ref.awp_loss_ref(w, th0, c))
    th = th0
    f = jax.jit(lambda *a: awp.awp_quant_chunk(*a, chunk=1, group=32))
    eta_q = jnp.float32(1.5 / float(jnp.linalg.norm(c)))
    best = loss0
    for _ in range(10):
        th, _, rel_l = f(w, th, c, eta_q, jnp.float32(7.0))
        wn = float(jnp.linalg.norm(w))
        best = min(best, (float(rel_l) * wn) ** 2)
    assert best < loss0


def test_joint_chunk_satisfies_both_constraints():
    w, c, eta = problem(5)
    k = w.shape[1] // 4
    th0 = wanda_init(w, c, k)
    th, _, _ = jax.jit(lambda *a: awp.awp_joint_chunk(*a, chunk=8, group=32))(
        w, th0, c, eta, jnp.int32(k), jnp.float32(15.0))
    th = np.asarray(th)
    assert ((th != 0).sum(axis=1) <= k).all()
    # non-zero entries sit on the per-group grid of the *pruned* iterate:
    reproj = np.asarray(ref.quant_project_ref(jnp.asarray(th), 15.0, group=32))
    mask = th != 0
    np.testing.assert_allclose(th[mask], reproj[mask], atol=1e-5)


def test_joint_chunk_qmax_zero_is_pure_pruning():
    """qmax <= 0 disables quantization (used by the §4.3 ramp schedule)."""
    w, c, eta = problem(6)
    k = w.shape[1] // 2
    th0 = wanda_init(w, c, k)
    a, _, _ = jax.jit(lambda *a_: awp.awp_joint_chunk(*a_, chunk=4, group=32))(
        w, th0, c, eta, jnp.int32(k), jnp.float32(0.0))
    b, _, _ = jax.jit(lambda *a_: awp.awp_prune_chunk(*a_, chunk=4))(
        w, th0, c, eta, jnp.int32(k))
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_chunk1_matches_chunk_n_composition():
    """Eight chunk=1 calls == one chunk=8 call (Figure-1 series validity)."""
    w, c, eta = problem(7)
    k = w.shape[1] // 2
    th0 = wanda_init(w, c, k)
    f1 = jax.jit(lambda *a: awp.awp_prune_chunk(*a, chunk=1))
    f8 = jax.jit(lambda *a: awp.awp_prune_chunk(*a, chunk=8))
    th_a = th0
    for _ in range(8):
        th_a, _, _ = f1(w, th_a, c, eta, jnp.int32(k))
    th_b, _, _ = f8(w, th0, c, eta, jnp.int32(k))
    np.testing.assert_allclose(th_a, th_b, rtol=1e-4, atol=1e-5)


def test_stats_scalars_are_finite_and_consistent():
    w, c, eta = problem(8)
    k = w.shape[1] // 2
    th, g, l = jax.jit(lambda *a: awp.awp_prune_chunk(*a, chunk=2))(
        w, wanda_init(w, c, k), c, eta, jnp.int32(k))
    wn = float(jnp.linalg.norm(w))
    want_l = float(np.sqrt(ref.awp_loss_ref(w, th, c))) / wn
    np.testing.assert_allclose(float(l), want_l, rtol=1e-4)
    r = np.asarray(w - th) @ np.asarray(c)
    np.testing.assert_allclose(float(g), np.linalg.norm(r) / wn, rtol=1e-4)
