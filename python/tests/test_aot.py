"""AOT pipeline: HLO-text emission and manifest integrity.

Uses a temp dir with the tiny model only, so the suite stays fast; the full
artifact set is exercised end-to-end by the Rust integration tests.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--models", "tiny", "--quiet"],
        cwd=os.path.join(REPO, "python"), capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    return out


def test_manifest_exists_and_parses(built):
    man = json.loads((built / "manifest.json").read_text())
    assert man["format"] == "hlo-text"
    assert "tiny" in man["models"]
    assert man["awp"]["chunk"] >= 1
    assert man["awp"]["group"] == 32


def test_all_referenced_files_exist(built):
    man = json.loads((built / "manifest.json").read_text())
    files = list(man["awp"]["programs"].values())
    for m in man["models"].values():
        files += list(m["programs"].values())
    for f in files:
        p = built / f
        assert p.exists() and p.stat().st_size > 100, f


def test_hlo_text_is_parseable_shape(built):
    """Every program is HLO text with an entry computation layout (what
    HloModuleProto::from_text_file needs) and never a serialized proto."""
    man = json.loads((built / "manifest.json").read_text())
    for f in list(man["awp"]["programs"].values())[:4]:
        head = (built / f).read_text()[:200]
        assert head.startswith("HloModule"), f
        assert "entry_computation_layout" in head, f


def test_param_order_matches_model_spec(built):
    from compile import model as M
    man = json.loads((built / "manifest.json").read_text())
    spec = M.param_spec(M.MODEL_SIZES["tiny"])
    got = [(p["name"], tuple(p["shape"])) for p in man["models"]["tiny"]["params"]]
    assert got == spec


def test_calib_capture_keeps_unused_params(built):
    """Regression: jax DCEs dead inputs (ln_f, last block's w_down are unused
    by calib_capture) unless lowered with keep_unused=True; the Rust side
    passes the FULL positional parameter list and would get an arity error.
    Count parameters in the entry computation layout."""
    from compile import model as M
    man = json.loads((built / "manifest.json").read_text())
    fname = man["models"]["tiny"]["programs"]["calib_capture"]
    head = (built / fname).read_text()[:4000]
    layout = head.split("entry_computation_layout={(")[1].split(")->")[0]
    n_args = layout.count("f32[") + layout.count("s32[")
    n_params = len(M.param_spec(M.MODEL_SIZES["tiny"]))
    assert n_args == n_params + 1, f"{n_args} args vs {n_params} params + tokens"


def test_awp_program_names_cover_all_shape_classes(built):
    from compile import model as M
    man = json.loads((built / "manifest.json").read_text())
    progs = man["awp"]["programs"]
    for cfg in [M.MODEL_SIZES["tiny"]]:
        d, ff = cfg.d_model, cfg.d_ff
        for (m, k) in [(d, d), (ff, d), (d, ff)]:
            for mode in ["prune", "prune1", "quant", "quant1", "joint",
                         "joint1"]:
                assert f"awp_{mode}_{m}x{k}" in progs
