"""L1 kernel correctness: Pallas vs pure-jnp oracle (hypothesis-swept).

This is the CORE correctness signal for the compute layer: every kernel that
ends up inside an AOT artifact must match ref.py bit-for-bit-ish (f32 matmul
reassociation tolerance) across shapes, tilings and parameter ranges.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def psd_gram(rng, d, n_factor=2):
    """A realistic activation Gram: C = X X^T / n, PSD with spread spectrum."""
    x = rng.normal(size=(d, n_factor * d)) * np.exp(rng.normal(size=(d, 1)))
    c = x @ x.T / (n_factor * d)
    return jnp.asarray(c, jnp.float32)


# ---------------------------------------------------------------------------
# pgd_step


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 12).map(lambda v: 8 * v),
    k=st.integers(1, 12).map(lambda v: 8 * v),
    tile=st.sampled_from([8, 16, 32, 64]),
    eta=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_pgd_step_matches_ref(m, k, tile, eta, seed):
    rng = np.random.default_rng(seed)
    w, th = rand(rng, m, k), rand(rng, m, k)
    c = psd_gram(rng, k)
    got = kernels.pgd_step(w, th, c, eta, tile_m=tile, tile_n=tile, tile_k=tile)
    want = ref.pgd_step_ref(w, th, c, eta)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_pgd_step_eta_zero_is_identity():
    rng = np.random.default_rng(0)
    w, th, c = rand(rng, 64, 32), rand(rng, 64, 32), psd_gram(rng, 32)
    out = kernels.pgd_step(w, th, c, 0.0)
    np.testing.assert_allclose(out, th, atol=1e-6)


def test_pgd_step_fixed_point():
    """Theta == W is a fixed point of the gradient step for any eta."""
    rng = np.random.default_rng(1)
    w, c = rand(rng, 32, 32), psd_gram(rng, 32)
    out = kernels.pgd_step(w, w, c, 0.3)
    np.testing.assert_allclose(out, w, atol=1e-6)


def test_pgd_step_non_square_tiles():
    rng = np.random.default_rng(2)
    w, th = rand(rng, 96, 160), rand(rng, 96, 160)
    c = psd_gram(rng, 160)
    got = kernels.pgd_step(w, th, c, 0.05, tile_m=32, tile_n=64, tile_k=16)
    want = ref.pgd_step_ref(w, th, c, 0.05)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_pgd_step_tile_larger_than_dim_falls_back():
    rng = np.random.default_rng(3)
    w, th, c = rand(rng, 8, 8), rand(rng, 8, 8), psd_gram(rng, 8)
    got = kernels.pgd_step(w, th, c, 0.1, tile_m=128, tile_n=128, tile_k=128)
    np.testing.assert_allclose(got, ref.pgd_step_ref(w, th, c, 0.1),
                               rtol=1e-5, atol=1e-4)


def test_pgd_step_rejects_bad_gram_shape():
    rng = np.random.default_rng(4)
    with pytest.raises(AssertionError):
        kernels.pgd_step(rand(rng, 8, 8), rand(rng, 8, 8),
                         rand(rng, 8, 16), 0.1)


def test_pgd_step_under_jit_and_grad_composes():
    """The kernel must be traceable inside jit (it lives in a fori_loop)."""
    rng = np.random.default_rng(5)
    w, th, c = rand(rng, 16, 16), rand(rng, 16, 16), psd_gram(rng, 16)
    f = jax.jit(lambda t: kernels.pgd_step(w, t, c, 0.1))
    np.testing.assert_allclose(f(th), ref.pgd_step_ref(w, th, c, 0.1),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# quant_project


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 8).map(lambda v: 4 * v),
    groups=st.integers(1, 6),
    group=st.sampled_from([8, 16, 32]),
    bits=st.sampled_from([2, 3, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_project_matches_ref(m, groups, group, bits, seed):
    rng = np.random.default_rng(seed)
    z = rand(rng, m, groups * group) * 3.0
    qmax = float(2**bits - 1)
    got = kernels.quant_project(z, qmax, group=group)
    want = ref.quant_project_ref(z, qmax, group=group)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(bits=st.sampled_from([2, 3, 4]), seed=st.integers(0, 2**31 - 1))
def test_quant_project_grid_membership(bits, seed):
    """Output lies on a (2^b)-point affine grid per group: the number of
    distinct values within each group is at most 2^bits."""
    rng = np.random.default_rng(seed)
    z = rand(rng, 4, 64) * 2.0
    qmax = float(2**bits - 1)
    out = np.asarray(kernels.quant_project(z, qmax, group=16))
    for row in out.reshape(4, 4, 16):
        for grp in row:
            assert len(np.unique(grp)) <= 2**bits


def test_quant_project_idempotent():
    """Projection is idempotent: Proj(Proj(z)) == Proj(z)."""
    rng = np.random.default_rng(7)
    z = rand(rng, 8, 64)
    p1 = kernels.quant_project(z, 15.0, group=32)
    p2 = kernels.quant_project(p1, 15.0, group=32)
    np.testing.assert_allclose(p1, p2, atol=1e-5)


def test_quant_project_flat_group():
    """A constant group must survive exactly (scale=0 guard)."""
    z = jnp.ones((2, 32), jnp.float32) * 0.7
    out = kernels.quant_project(z, 15.0, group=32)
    np.testing.assert_allclose(out, z, atol=1e-7)


def test_quant_project_error_bounded_by_half_step():
    rng = np.random.default_rng(8)
    z = rand(rng, 16, 64)
    qmax = 15.0
    out = np.asarray(kernels.quant_project(z, qmax, group=32))
    zg = np.asarray(z).reshape(16, 2, 32)
    step = (zg.max(-1) - zg.min(-1)) / qmax    # per-group grid step
    err = np.abs(out.reshape(16, 2, 32) - zg).max(-1)
    assert (err <= step / 2 + 1e-6).all()


# ---------------------------------------------------------------------------
# topk_rows (L2 projection, used inside all pruning programs)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 16),
    d=st.integers(4, 64),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_topk_rows_exact_k(m, d, seed, data):
    k = data.draw(st.integers(1, d))
    rng = np.random.default_rng(seed)
    # tie-free by construction: distinct magnitudes
    mags = rng.permutation(m * d).reshape(m, d).astype(np.float32) + 1.0
    signs = np.where(rng.random((m, d)) < 0.5, -1.0, 1.0)
    z = jnp.asarray(mags * signs)
    out = np.asarray(ref.topk_rows_ref(z, jnp.int32(k)))
    nnz = (out != 0).sum(axis=1)
    assert (nnz == k).all()
    # surviving entries are exactly the k largest magnitudes, kept verbatim
    za = np.abs(np.asarray(z))
    for i in range(m):
        keep = np.argsort(-za[i])[:k]
        assert set(np.nonzero(out[i])[0]) == set(keep)
        np.testing.assert_array_equal(out[i][keep], np.asarray(z)[i][keep])


def test_topk_rows_k_ge_d_keeps_all():
    rng = np.random.default_rng(9)
    z = rand(rng, 4, 16)
    out = ref.topk_rows_ref(z, jnp.int32(16))
    np.testing.assert_allclose(out, z)


def test_topk_rows_k_clamped_at_one():
    rng = np.random.default_rng(10)
    z = rand(rng, 4, 16)
    out = np.asarray(ref.topk_rows_ref(z, jnp.int32(0)))
    assert ((out != 0).sum(axis=1) <= 1).all()


# ---------------------------------------------------------------------------
# awp loss identity


@settings(max_examples=20, deadline=None)
@given(d=st.integers(2, 24), seed=st.integers(0, 2**31 - 1))
def test_awp_loss_trace_identity(d, seed):
    """sum(R * (R@C)) == ||R C^{1/2}||_F^2 (Appendix B) — checked against an
    explicit matrix square root via eigendecomposition."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(d + 3, d)).astype(np.float32)
    th = rng.normal(size=(d + 3, d)).astype(np.float32)
    c = np.asarray(psd_gram(rng, d), np.float64)
    evals, evecs = np.linalg.eigh(c)
    csqrt = evecs @ np.diag(np.sqrt(np.maximum(evals, 0))) @ evecs.T
    want = np.linalg.norm((w - th).astype(np.float64) @ csqrt, "fro") ** 2
    got = float(ref.awp_loss_ref(jnp.asarray(w), jnp.asarray(th),
                                 jnp.asarray(c, jnp.float32)))
    np.testing.assert_allclose(got, want, rtol=2e-3)
