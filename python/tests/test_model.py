"""L2 model semantics: shapes, loss sanity, training signal, Gram capture."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(name="test", d_model=64, n_heads=4, n_layers=2,
                    d_ff=128, seq_len=32, batch=2)


def toks(seed=0, cfg=CFG):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)),
                       jnp.int32)


def test_param_spec_shapes_cover_all_sites():
    spec = dict(M.param_spec(CFG))
    assert spec["embed"] == (256, 64)
    for i in range(CFG.n_layers):
        assert spec[f"blocks.{i}.wq"] == (64, 64)
        assert spec[f"blocks.{i}.w_up"] == (128, 64)
        assert spec[f"blocks.{i}.w_down"] == (64, 128)
    # 1 embed + 8 per block + final norm
    assert len(spec) == 1 + 8 * CFG.n_layers + 1


def test_flatten_unflatten_roundtrip():
    params = M.init_params(CFG, 0)
    flat = M.flatten(CFG, params)
    back = M.unflatten(CFG, flat)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(back[k], params[k])


def test_forward_shapes_and_finiteness():
    params = M.init_params(CFG, 0)
    logits = M.forward(CFG, params, toks())
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_nll_near_uniform():
    params = M.init_params(CFG, 0)
    total, count = M.nll(CFG, params, toks())
    per_tok = float(total) / float(count)
    assert abs(per_tok - np.log(CFG.vocab)) < 0.5


def test_causality():
    """Changing a future token must not affect past logits."""
    params = M.init_params(CFG, 0)
    t1 = toks(1)
    t2 = t1.at[:, -1].set((t1[:, -1] + 7) % CFG.vocab)
    l1 = M.forward(CFG, params, t1)
    l2 = M.forward(CFG, params, t2)
    np.testing.assert_allclose(l1[:, :-1, :], l2[:, :-1, :], atol=1e-5)


def test_rope_makes_model_position_sensitive():
    params = M.init_params(CFG, 0)
    t = toks(2)
    rolled = jnp.roll(t, 1, axis=1)
    l1 = M.forward(CFG, params, t)
    l2 = M.forward(CFG, params, rolled)
    assert not np.allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                           atol=1e-4)


def test_train_step_decreases_loss_on_repeated_batch():
    params = M.init_params(CFG, 0)
    flat = M.flatten(CFG, params)
    zeros = [jnp.zeros_like(p) for p in flat]
    step_fn = jax.jit(M.make_train_step(CFG))
    t = toks(3)
    n = len(flat)
    state = list(flat) + list(zeros) + list(zeros)
    losses = []
    for s in range(8):
        out = step_fn(*state, t, jnp.float32(3e-3), jnp.float32(s))
        state = list(out[:-1])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] - 0.1, losses


def test_calib_capture_grams_are_psd_and_correct_scale():
    params = M.init_params(CFG, 0)
    prog = M.make_calib_capture(CFG)
    out = prog(*M.flatten(CFG, params), toks(4))
    attn_in, attn_out_in, mlp_in, mlp_down_in, count = out
    assert attn_in.shape == (CFG.n_layers, 64, 64)
    assert mlp_down_in.shape == (CFG.n_layers, 128, 128)
    assert float(count) == CFG.batch * CFG.seq_len
    for g in [attn_in, attn_out_in, mlp_in, mlp_down_in]:
        for layer in np.asarray(g):
            np.testing.assert_allclose(layer, layer.T, atol=1e-3)
            evals = np.linalg.eigvalsh(layer.astype(np.float64))
            assert evals.min() > -1e-2 * max(1.0, evals.max())


def test_calib_grams_match_manual_recompute():
    """attn_in gram of layer 0 == X X^T of the ln1 output, by hand."""
    params = M.init_params(CFG, 0)
    t = toks(5)
    prog = M.make_calib_capture(CFG)
    attn_in = prog(*M.flatten(CFG, params), t)[0]
    x = params["embed"][t]
    h = x * params["blocks.0.ln1"] * jax.lax.rsqrt(
        jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)
    flat = h.reshape(-1, CFG.d_model)
    np.testing.assert_allclose(attn_in[0], flat.T @ flat, rtol=1e-4, atol=1e-2)


def test_decode_step_matches_forward():
    cfg = M.ModelConfig(name="t", d_model=64, n_heads=4, n_layers=2,
                        d_ff=128, seq_len=32, batch=2, decode_len=16)
    params = M.init_params(cfg, 0)
    rng = np.random.default_rng(6)
    t = jnp.asarray(rng.integers(0, 256, (1, 16)), jnp.int32)
    (logits,) = M.make_decode_step(cfg)(*M.flatten(cfg, params), t)
    full = M.forward(cfg, params, t)
    np.testing.assert_allclose(logits, full[0, -1], atol=1e-5)


def test_model_sizes_param_counts():
    """The ladder documented in DESIGN.md §2."""
    for name, lo, hi in [("tiny", 0.7e6, 1.0e6), ("small", 3.0e6, 3.6e6),
                         ("medium", 10.0e6, 11.5e6)]:
        cfg = M.MODEL_SIZES[name]
        n = sum(int(np.prod(s)) for _, s in M.param_spec(cfg))
        assert lo < n < hi, (name, n)
